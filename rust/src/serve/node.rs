//! In-process edge serving node: KiSS coordination over real PJRT
//! executables.
//!
//! Container semantics in live mode:
//!
//! * **Cold start** — the function's HLO artifact is compiled *afresh*
//!   (a genuine per-container initialization cost, measured), then run.
//! * **Warm hit** — the container's existing executable runs immediately.
//! * **Drop** — the KiSS balancer found no capacity; the request would be
//!   punted to the cloud.
//!
//! Memory accounting uses the function profiles (as the platform would:
//! declared container sizes), while latency/throughput are *measured*
//! wall-clock over real inference.

// Determinism-contract exemption (see rust/clippy.toml): live serving
// measures real wall-clock latency and its container table never feeds
// simulation state, so the D01/D03 backstop lints do not apply.
#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::SimConfig;
use crate::coordinator::{Balancer, ContainerId, Dispatcher, Outcome};
use crate::metrics::{RecordKind, Report};
use crate::runtime::{load_manifest, Engine, LoadedPayload, PayloadSpec};
use crate::trace::{FunctionId, FunctionProfile};

/// A deployed function: platform profile + which AOT payload it runs.
#[derive(Clone, Debug)]
pub struct LiveFunction {
    /// Platform profile (declared memory, size class, dense id).
    pub profile: FunctionProfile,
    /// Payload name in the artifact manifest (batch-1 variant).
    pub payload: String,
}

/// One invocation's result.
#[derive(Debug)]
pub struct InvokeResult {
    /// How the request was served ([`RecordKind::Hit`] / `Miss` / `Drop`).
    pub outcome_kind: RecordKind,
    /// End-to-end latency (cold compile + execute, or execute only).
    pub latency: Duration,
    /// Model output (empty when dropped).
    pub output: Vec<f32>,
}

struct LiveContainer {
    exe: LoadedPayload,
}

/// The serving node.
pub struct EdgeNode {
    balancer: Balancer,
    engine: Engine,
    specs: HashMap<String, PayloadSpec>,
    functions: Vec<LiveFunction>,
    containers: HashMap<ContainerId, LiveContainer>,
    epoch: Instant,
    /// Rolling serve metrics, same shape as a simulation [`Report`].
    pub report: Report,
}

impl EdgeNode {
    /// Build a node from a config and the artifact directory. Registers
    /// no functions yet — call [`EdgeNode::deploy`].
    pub fn new(cfg: &SimConfig, artifacts_dir: &Path) -> Result<Self> {
        let engine = Engine::cpu()?;
        let specs = load_manifest(artifacts_dir)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        Ok(Self {
            balancer: cfg.build_balancer(),
            engine,
            specs,
            functions: Vec::new(),
            containers: HashMap::new(),
            epoch: Instant::now(),
            report: Report::default(),
        })
    }

    /// Deploy a function backed by `payload` (must exist in the manifest).
    /// Returns its id. Function ids are dense, in deployment order.
    pub fn deploy(&mut self, mut profile: FunctionProfile, payload: &str) -> Result<FunctionId> {
        if !self.specs.contains_key(payload) {
            bail!(
                "unknown payload {payload:?}; available: {:?}",
                self.specs.keys().collect::<Vec<_>>()
            );
        }
        let id = FunctionId(self.functions.len() as u32);
        profile.id = id;
        self.functions.push(LiveFunction { profile, payload: payload.to_string() });
        Ok(id)
    }

    /// Look up a deployed function by id.
    pub fn function(&self, id: FunctionId) -> Option<&LiveFunction> {
        self.functions.get(id.0 as usize)
    }

    /// Every deployed function, in deployment (= id) order.
    pub fn functions(&self) -> &[LiveFunction] {
        &self.functions
    }

    /// Microseconds since the node started — the live clock fed to the
    /// balancer in place of the simulator's virtual time.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Per-pool `(used_mb, capacity_mb)` pairs from the balancer.
    pub fn occupancy(&self) -> Vec<(u64, u64)> {
        self.balancer.occupancy()
    }

    /// One-line description of the balancer configuration.
    pub fn describe(&self) -> String {
        self.balancer.describe()
    }

    fn spec_for(&self, payload: &str, batch: usize) -> Result<&PayloadSpec> {
        // Payload names end in `_b<batch>`; swap the suffix.
        let stem = payload
            .rsplit_once("_b")
            .map(|(s, _)| s)
            .ok_or_else(|| anyhow!("payload {payload:?} has no _b<batch> suffix"))?;
        let name = format!("{stem}_b{batch}");
        self.specs
            .get(&name)
            .ok_or_else(|| anyhow!("no batch-{batch} artifact for {stem:?}"))
    }

    /// Available batch sizes for a function's payload family (ascending).
    pub fn batch_sizes(&self, id: FunctionId) -> Vec<usize> {
        let Some(f) = self.function(id) else { return Vec::new() };
        let Some((stem, _)) = f.payload.rsplit_once("_b") else { return Vec::new() };
        let mut sizes: Vec<usize> = self
            .specs
            .keys()
            .filter_map(|n| n.rsplit_once("_b").filter(|(s, _)| *s == stem))
            .filter_map(|(_, b)| b.parse().ok())
            .collect();
        sizes.sort_unstable();
        sizes
    }

    /// Invoke a function on one input (batch = 1).
    pub fn invoke(&mut self, id: FunctionId, input: &[f32]) -> Result<InvokeResult> {
        self.invoke_batch(id, input, 1)
    }

    /// Invoke a function on a packed batch of `batch` inputs (a batch
    /// executes inside one container, as formed by the [`super::Batcher`]).
    pub fn invoke_batch(
        &mut self,
        id: FunctionId,
        input: &[f32],
        batch: usize,
    ) -> Result<InvokeResult> {
        let f = self
            .functions
            .get(id.0 as usize)
            .ok_or_else(|| anyhow!("unknown function {id:?}"))?
            .clone();
        let spec = self.spec_for(&f.payload, batch)?.clone();
        if input.len() != spec.input_len() {
            bail!(
                "{}: batch-{batch} input len {} != {}",
                f.payload,
                input.len(),
                spec.input_len()
            );
        }

        let t0 = Instant::now();
        let now = self.now_us();
        let outcome = self.balancer.dispatch(&f.profile, now);
        let result = match outcome {
            Outcome::Drop => {
                self.report.record(f.profile.class, RecordKind::Drop, 0, 0);
                InvokeResult {
                    outcome_kind: RecordKind::Drop,
                    latency: t0.elapsed(),
                    output: Vec::new(),
                }
            }
            Outcome::Cold { pool, container } => {
                // Real initialization: compile the artifact afresh.
                let exe = self.engine.compile_fresh(&spec)?;
                let output = exe.run(input)?;
                self.containers.insert(container, LiveContainer { exe });
                let latency = t0.elapsed();
                self.balancer.release(pool, container, self.now_us());
                self.report.record(
                    f.profile.class,
                    RecordKind::Miss,
                    latency.as_micros() as u64,
                    0,
                );
                InvokeResult { outcome_kind: RecordKind::Miss, latency, output }
            }
            Outcome::Hit { pool, container } => {
                // A warm container exists, but it may hold a different
                // batch variant: recompile counts as part of the warm path
                // only when the variant changes (rare under the batcher).
                let needs_swap = self
                    .containers
                    .get(&container)
                    .map(|c| c.exe.spec.name != spec.name)
                    .unwrap_or(true);
                if needs_swap {
                    let exe = self.engine.compile_fresh(&spec)?;
                    self.containers.insert(container, LiveContainer { exe });
                }
                let output = self.containers[&container].exe.run(input)?;
                let latency = t0.elapsed();
                self.balancer.release(pool, container, self.now_us());
                self.report.record(
                    f.profile.class,
                    RecordKind::Hit,
                    latency.as_micros() as u64,
                    0,
                );
                InvokeResult { outcome_kind: RecordKind::Hit, latency, output }
            }
        };

        // Garbage-collect evicted containers' executables.
        self.containers
            .retain(|id, _| self.balancer.pools().iter().any(|p| p.container(*id).is_some()));
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SizeClass;

    pub(crate) fn mlp_profile(mem_mb: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(0),
            app_id: 0,
            mem_mb,
            app_mem_mb: mem_mb,
            cold_start_us: 0,
            warm_start_us: 0,
            exec_us_mean: 0,
            class: if mem_mb >= 200 { SizeClass::Large } else { SizeClass::Small },
            slo_ms: None,
        }
    }

    // PJRT-backed tests live in rust/tests/integration_serve.rs; here we
    // only test pure logic that needs no engine.
    #[test]
    fn batch_suffix_parsing() {
        // spec_for logic is exercised via the integration tests; check the
        // suffix convention assumption holds for manifest names.
        let name = "iot_mlp_b8";
        let (stem, b) = name.rsplit_once("_b").unwrap();
        assert_eq!(stem, "iot_mlp");
        assert_eq!(b, "8");
    }
}
