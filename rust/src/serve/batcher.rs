//! Dynamic batcher: packs queued requests for the same function into the
//! largest AOT batch variant available, falling back to singles.
//!
//! The AOT pipeline compiles each payload at a fixed set of batch sizes
//! (e.g. `iot_mlp_b1`, `iot_mlp_b8`); XLA executables are shape-static,
//! so batching is a *selection* problem: given `n` queued requests and
//! available sizes `S`, emit the largest `s ∈ S, s ≤ n` repeatedly.
//! This is the standard serving pattern (vLLM-style bucketed batching)
//! adapted to PJRT's static shapes.

/// Plan for draining a queue of `n` same-function requests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// Batch sizes to execute, in order; sums to the planned count.
    pub batches: Vec<usize>,
    /// Requests left unplanned (only when no size-1 artifact exists).
    pub remainder: usize,
}

/// Compute the batch plan for `queued` requests over `sizes` (ascending
/// list of available batch variants).
pub fn plan(queued: usize, sizes: &[usize]) -> BatchPlan {
    let mut batches = Vec::new();
    let mut left = queued;
    loop {
        let Some(&best) = sizes.iter().rev().find(|&&s| s <= left) else {
            break;
        };
        batches.push(best);
        left -= best;
    }
    BatchPlan { batches, remainder: left }
}

/// A simple accumulation batcher: push requests, drain when either the
/// largest batch size is reachable or the deadline expires.
pub struct Batcher {
    sizes: Vec<usize>,
    pending: Vec<Vec<f32>>,
    /// Max requests to hold before forcing a drain.
    high_watermark: usize,
}

impl Batcher {
    /// `sizes` = the payload's available batch variants (ascending).
    pub fn new(mut sizes: Vec<usize>) -> Self {
        sizes.sort_unstable();
        sizes.dedup();
        assert!(!sizes.is_empty(), "batcher needs at least one batch size");
        let high = *sizes.last().unwrap();
        Self { sizes, pending: Vec::new(), high_watermark: high }
    }

    /// Queue one request's flat input.
    pub fn push(&mut self, input: Vec<f32>) {
        self.pending.push(input);
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// True when a full largest-variant batch is ready.
    pub fn should_drain(&self) -> bool {
        self.pending.len() >= self.high_watermark
    }

    /// Drain everything currently queued into concatenated batch inputs:
    /// returns `(batch_size, packed_input)` per executable call, in
    /// arrival order. Requests that cannot be planned (no b1 artifact)
    /// stay queued.
    pub fn drain(&mut self) -> Vec<(usize, Vec<f32>)> {
        let p = plan(self.pending.len(), &self.sizes);
        let mut out = Vec::with_capacity(p.batches.len());
        let mut taken = self.pending.drain(..self.pending.len() - p.remainder);
        for b in p.batches {
            let mut packed = Vec::new();
            for _ in 0..b {
                packed.extend(taken.next().expect("plan covers drained requests"));
            }
            out.push((b, packed));
        }
        drop(taken);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_prefers_largest_batches() {
        assert_eq!(plan(17, &[1, 8]).batches, vec![8, 8, 1]);
        assert_eq!(plan(17, &[1, 8]).remainder, 0);
        assert_eq!(plan(7, &[1, 8]).batches, vec![1; 7]);
        assert_eq!(plan(3, &[1, 2]).batches, vec![2, 1]);
    }

    #[test]
    fn plan_reports_remainder_without_b1() {
        let p = plan(5, &[2]);
        assert_eq!(p.batches, vec![2, 2]);
        assert_eq!(p.remainder, 1);
    }

    #[test]
    fn plan_empty_queue() {
        assert_eq!(plan(0, &[1, 8]), BatchPlan { batches: vec![], remainder: 0 });
    }

    #[test]
    fn batcher_packs_in_arrival_order() {
        let mut b = Batcher::new(vec![1, 2]);
        b.push(vec![1.0, 1.0]);
        b.push(vec![2.0, 2.0]);
        b.push(vec![3.0, 3.0]);
        assert!(b.should_drain());
        let drained = b.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], (2, vec![1.0, 1.0, 2.0, 2.0]));
        assert_eq!(drained[1], (1, vec![3.0, 3.0]));
        assert!(b.is_empty());
    }

    #[test]
    fn batcher_holds_remainder_without_b1() {
        let mut b = Batcher::new(vec![2]);
        b.push(vec![1.0]);
        b.push(vec![2.0]);
        b.push(vec![3.0]);
        let drained = b.drain();
        assert_eq!(drained, vec![(2, vec![1.0, 2.0])]);
        assert_eq!(b.len(), 1, "unplannable request stays queued");
    }

    #[test]
    fn watermark_matches_largest_size() {
        let b = Batcher::new(vec![8, 1]);
        assert!(!b.should_drain());
        assert_eq!(b.high_watermark, 8);
    }
}
