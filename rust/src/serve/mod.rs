//! Live serving path: the same KiSS coordinator that the simulator
//! drives, but attached to the real PJRT runtime — containers hold
//! actually-compiled HLO executables and invocations run real inference.
//!
//! * [`node`] — [`node::EdgeNode`]: in-process serving node (the
//!   end-to-end example drives this directly).
//! * [`batcher`] — dynamic batcher that packs compatible requests into
//!   the largest available AOT batch variant.
//! * [`server`] — a threaded TCP front (line protocol) over an EdgeNode.
//!
//! Python never appears here: artifacts are compiled ahead of time and
//! the request path is pure Rust + PJRT.

pub mod batcher;
pub mod node;
pub mod server;

pub use batcher::Batcher;
pub use node::{EdgeNode, InvokeResult, LiveFunction};
