//! `repro` — the kiss-faas launcher.
//!
//! ```text
//! repro experiment <id|group|all|list|index> [--format text|json|csv]
//!                [--out DIR] [--jobs N] [--seed N] [--scale F] [--stress-scale F]
//! repro simulate [--config FILE] [--mem-gb N] [--baseline] [--split F]
//!                [--policy lru|gd|freq] [--seed N]
//! repro cluster  [--config FILE] [--nodes N] [--router R] [--small-nodes N]
//!                [--fallbacks N] [--cloud-rtt-ms F] [--mem-gb N]
//!                [--migration-cost-ms F] [--controller-epoch-s N]
//!                [--topology flat|star|ring] [--hop-ms F]
//!                [--churn-rate F] [--sweep]
//!                [--slo-ms N] [--slo-fairshare-window-s F] [--slo-deflate-pressure F]
//!                [--source synth|replay|closed-loop] [--trace STEM]
//!                [--clients N] [--think-ms N]
//!                [--shards N] [--window-us N] [--shard-mode exact|approx]
//! repro analyze  [--seed N] [--duration-s N]      # Figs 2–5 on a fresh trace
//! repro trace    --out STEM [--seed N] [--duration-s N] [--rate F]
//! repro serve    [--port P] [--mem-gb N] [--artifacts DIR]
//! repro selfcheck [--artifacts DIR]               # load + verify payloads
//! repro bench-json [--out FILE] [--trials N] [--scale F]  # perf record
//! ```
//!
//! Argument parsing is hand-rolled (no clap offline — see crate docs);
//! unknown flags are hard errors, not silent ignores.

// Determinism-contract exemption (see rust/clippy.toml): CLI flag
// parsing is lookup-only — no iteration order ever reaches output.
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use kiss_faas::config::{Mode, SimConfig, WorkloadSourceKind};
use kiss_faas::coordinator::policy::PolicyKind;
use kiss_faas::experiments::{self, run_single, ExpParams, Experiment, Group};
use kiss_faas::serve::node::EdgeNode;
use kiss_faas::serve::server::Server;
use kiss_faas::sim::cluster::{
    plan_sharding, run_cluster_sharded, MigrationPolicy, RouterKind, ShardMode, Topology,
};
use kiss_faas::trace::synth::{synthesize, SynthConfig};
use kiss_faas::trace::{loader, FunctionId, FunctionProfile, SizeClass};
use kiss_faas::util::json::Json;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "experiment" => cmd_experiment(&flags),
        "simulate" => cmd_simulate(&flags),
        "cluster" => cmd_cluster(&flags),
        "analyze" => cmd_analyze(&flags),
        "trace" => cmd_trace(&flags),
        "serve" => cmd_serve(&flags),
        "selfcheck" => cmd_selfcheck(&flags),
        "bench-json" => cmd_bench_json(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        "kiss-faas repro — KiSS: Keep it Separated Serverless (paper reproduction)\n\n\
         USAGE:\n  repro experiment <id|group|all|list|index> [--format text|json|csv] [--out DIR]\n                \
         [--jobs N] [--seed N] [--scale F] [--stress-scale F]\n  \
         repro simulate [--config FILE] [--mem-gb N] [--baseline] [--split F] [--policy P] [--seed N]\n  \
         repro cluster [--config FILE] [--nodes N] [--router R] [--small-nodes N] [--fallbacks N] [--cloud-rtt-ms F]\n                [--migration-cost-ms F] [--controller-epoch-s N] [--topology T] [--hop-ms F] [--churn-rate F] [--sweep]\n                [--slo-ms N] [--slo-fairshare-window-s F] [--slo-deflate-pressure F]\n                [--source synth|replay|closed-loop] [--trace STEM] [--clients N] [--think-ms N] [--shards N] [--window-us N] [--shard-mode exact|approx]\n  \
         repro analyze [--seed N] [--duration-s N]\n  \
         repro trace --out STEM [--seed N] [--duration-s N] [--rate F]\n  \
         repro serve [--port P] [--mem-gb N] [--artifacts DIR]\n  \
         repro selfcheck [--artifacts DIR]\n  \
         repro bench-json [--out FILE] [--trials N] [--scale F]\n\n\
         EXPERIMENTS (from the registry — `repro experiment list` for details):\n{}",
        experiments::usage_summary()
    );
}

/// `--flag value` / `--flag` (bool) parser; positionals kept in order.
struct Flags {
    positional: Vec<String>,
    named: HashMap<String, String>,
}

const BOOL_FLAGS: [&str; 3] = ["--baseline", "--verbose", "--sweep"];

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&a.as_str()) {
                    named.insert(name.to_string(), "true".to_string());
                } else {
                    let v = args
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    named.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { positional, named })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.named.get(name).map(|s| s.as_str())
    }

    fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.get(name)
            .map(|v| v.parse::<T>().map_err(|e| anyhow!("--{name}: {e}")))
            .transpose()
    }

    fn has(&self, name: &str) -> bool {
        self.named.contains_key(name)
    }
}

/// Output format of `repro experiment`.
#[derive(Clone, Copy, PartialEq, Eq)]
enum ArtifactFormat {
    Text,
    Json,
    Csv,
}

impl ArtifactFormat {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "text" => Some(Self::Text),
            "json" => Some(Self::Json),
            "csv" => Some(Self::Csv),
            _ => None,
        }
    }

    fn extension(self) -> &'static str {
        match self {
            Self::Text => "txt",
            Self::Json => "json",
            Self::Csv => "csv",
        }
    }
}

/// Resolve an `experiment` selector to registry entries: an id, a group
/// label, or `all` (everything, in registry order — stress included).
fn select_experiments(name: &str) -> Result<Vec<&'static Experiment>> {
    if name == "all" {
        return Ok(experiments::registry().iter().collect());
    }
    if let Some(group) = Group::parse(name) {
        return Ok(experiments::by_group(group));
    }
    match experiments::find(name) {
        Some(e) => Ok(vec![e]),
        None => bail!(
            "unknown experiment {name:?} (ids: {}; groups: {})",
            experiments::ALL_EXPERIMENTS.join(", "),
            Group::ALL.map(Group::label).join(", ")
        ),
    }
}

fn cmd_experiment(flags: &Flags) -> Result<()> {
    let name = flags.positional.first().ok_or_else(|| {
        anyhow!("experiment selector required (an id, a group, all, list, or index)")
    })?;
    match name.as_str() {
        // `list`: one tab-separated line per registry entry (stable
        // machine-readable surface — CI counts artifacts against it).
        "list" => {
            for e in experiments::registry() {
                let m = &e.meta;
                println!("{}\t{}\t{}\t{}", m.id, m.group.label(), m.paper_ref, m.title);
            }
            return Ok(());
        }
        // `index`: the generated markdown catalog for docs/EXPERIMENTS.md.
        "index" => {
            print!("{}", experiments::catalog_markdown());
            return Ok(());
        }
        _ => {}
    }
    let selected = select_experiments(name)?;

    let format = match flags.get("format") {
        None => ArtifactFormat::Text,
        Some(f) => ArtifactFormat::parse(f)
            .ok_or_else(|| anyhow!("bad --format {f:?} (text|json|csv)"))?,
    };
    let out_dir = flags.get("out").map(PathBuf::from);
    let jobs: usize = flags.get_parsed("jobs")?.unwrap_or(1);
    if jobs == 0 {
        bail!("--jobs must be >= 1");
    }
    let seed = flags.get_parsed::<u64>("seed")?;
    let scale: f64 = flags.get_parsed("scale")?.unwrap_or(1.0);
    if scale <= 0.0 || !scale.is_finite() {
        bail!("--scale must be a positive finite factor");
    }
    // Back-compat: --stress-scale scales the stress experiment only.
    let stress_scale: Option<f64> = flags.get_parsed("stress-scale")?;
    if stress_scale.is_some_and(|s| s <= 0.0 || !s.is_finite()) {
        bail!("--stress-scale must be a positive finite factor");
    }

    let params_for = |e: &Experiment| ExpParams {
        seed,
        scale: match stress_scale {
            Some(s) if e.meta.id == "stress" => s,
            _ => scale,
        },
    };
    let render = |e: &Experiment| -> String {
        let params = params_for(e);
        let artifact = e.run(&params);
        match format {
            ArtifactFormat::Text => artifact.render_text(),
            ArtifactFormat::Json => e.artifact_json(&params, &artifact).to_string_pretty(),
            ArtifactFormat::Csv => artifact.render_csv(),
        }
    };

    // Fan the runs out over a worker pool (compute only — files and
    // stdout are written afterwards, in registry order, so output and
    // error behavior are deterministic regardless of --jobs).
    let rendered: Vec<String> = if jobs == 1 || selected.len() == 1 {
        selected.iter().map(|e| render(e)).collect()
    } else {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Mutex;
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<String>>> =
            selected.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..jobs.min(selected.len()) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(e) = selected.get(i) else { break };
                    let out = render(e);
                    *slots[i].lock().unwrap() = Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
            .collect()
    };

    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating --out {}", dir.display()))?;
        for (e, out) in selected.iter().zip(&rendered) {
            let path = dir.join(format!("{}.{}", e.meta.id, format.extension()));
            std::fs::write(&path, out).with_context(|| format!("writing {}", path.display()))?;
            println!("wrote {}", path.display());
        }
    } else {
        for out in &rendered {
            println!("{out}");
        }
    }
    Ok(())
}

fn build_sim_config(flags: &Flags) -> Result<SimConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => SimConfig::from_toml_file(Path::new(path))?,
        None => SimConfig::edge_default(8 * 1024),
    };
    if let Some(gb) = flags.get_parsed::<u64>("mem-gb")? {
        cfg.node_mem_mb = gb * 1024;
    }
    if flags.has("baseline") {
        cfg.mode = Mode::Baseline;
    } else if let Some(split) = flags.get_parsed::<f64>("split")? {
        cfg.mode = Mode::Kiss {
            small_frac: split,
            threshold_mb: kiss_faas::config::DEFAULT_THRESHOLD_MB,
        };
    }
    if let Some(p) = flags.get("policy") {
        let kind = PolicyKind::parse(p).ok_or_else(|| anyhow!("bad --policy {p:?}"))?;
        cfg.small_policy = kind;
        cfg.large_policy = kind;
    }
    if let Some(seed) = flags.get_parsed::<u64>("seed")? {
        cfg.synth.seed = seed;
    }
    if let Some(d) = flags.get_parsed::<u64>("duration-s")? {
        cfg.synth.duration_us = d * 1_000_000;
    }
    if let Some(r) = flags.get_parsed::<f64>("rate")? {
        cfg.synth.rate_per_sec = r;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_simulate(flags: &Flags) -> Result<()> {
    let cfg = build_sim_config(flags)?;
    println!("# {}", cfg.describe());
    let r = run_single(&cfg);
    println!(
        "{:>14} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "slice", "hits", "misses", "drops", "coldstart%", "drop%"
    );
    for (name, c) in [("overall", &r.overall), ("small", &r.small), ("large", &r.large)] {
        println!(
            "{:>14} {:>10} {:>10} {:>10} {:>12.2} {:>12.2}",
            name,
            c.hits,
            c.misses,
            c.drops,
            c.cold_start_pct(),
            c.drop_pct()
        );
    }
    println!("\nlatency ms (p50/p95/p99): {}", r.latency().summary_ms());
    Ok(())
}

/// `repro bench-json` — wall-clock timing of the end-to-end hot paths
/// (`run_trace` + `run_cluster`, sequential and sharded) at fixed
/// seeds, written as a schema-tagged JSON perf record. Defaults to
/// `BENCH_7.json` in the working directory (run from the repository
/// root to continue the perf trajectory there); CI's perf-smoke step
/// runs it at reduced scale.
fn cmd_bench_json(flags: &Flags) -> Result<()> {
    let trials: usize = flags.get_parsed("trials")?.unwrap_or(3);
    if trials == 0 {
        bail!("--trials must be >= 1");
    }
    let scale: f64 = flags.get_parsed("scale")?.unwrap_or(1.0);
    if scale <= 0.0 || !scale.is_finite() {
        bail!("--scale must be a positive finite factor");
    }
    let out = PathBuf::from(flags.get("out").unwrap_or("BENCH_7.json"));
    let doc = kiss_faas::bench::wallclock::run(trials, scale);
    if let Some(cases) = doc.get("cases").and_then(Json::as_arr) {
        for case in cases {
            let name = case.get("name").and_then(Json::as_str);
            let mean = case.get("mean_ms").and_then(Json::as_f64);
            if let (Some(name), Some(mean)) = (name, mean) {
                println!("{name:<40} mean {mean:>10.2} ms over {trials} trial(s)");
            }
        }
    }
    std::fs::write(&out, doc.to_string_pretty())
        .with_context(|| format!("writing {}", out.display()))?;
    println!("wrote {}", out.display());
    Ok(())
}

/// `repro cluster` — run one multi-node simulation (or, with `--sweep`,
/// the whole cluster experiment family).
fn cmd_cluster(flags: &Flags) -> Result<()> {
    if flags.has("sweep") {
        // One grid run yields both scale tables; hetero is its own grid.
        let synth = experiments::cluster::cluster_workload();
        let (scale, offload) = experiments::cluster::cluster_scale_and_offload(&synth);
        println!("{}", scale.render());
        println!("{}", offload.render());
        println!("{}", experiments::cluster::cluster_hetero(&synth).render());
        println!("{}", experiments::cluster::cluster_migration(&synth).render());
        println!("{}", experiments::cluster::cluster_controller(&synth).render());
        println!("{}", experiments::cluster::cluster_topology(&synth).render());
        println!("{}", experiments::cluster::cluster_churn(&synth).render());
        println!("{}", experiments::cluster::cluster_slo(&synth).render());
        println!("{}", experiments::cluster::cluster_fairshare(&synth).render());
        return Ok(());
    }

    let mut cfg = build_sim_config(flags)?;
    let mut cc = cfg.cluster.clone().unwrap_or_default();
    if let Some(n) = flags.get_parsed::<usize>("nodes")? {
        cc.nodes = n;
    }
    let small_nodes = flags.get_parsed::<usize>("small-nodes")?;
    if let Some(r) = flags.get("router") {
        cc.router = RouterKind::parse(r, small_nodes.unwrap_or(0)).ok_or_else(|| {
            anyhow!("bad --router {r:?} (round-robin|least-loaded|size-affinity|sticky)")
        })?;
    } else if let Some(k) = small_nodes {
        cc.router = RouterKind::SizeAffinity { small_nodes: k };
    }
    if let Some(f) = flags.get_parsed::<usize>("fallbacks")? {
        cc.fallbacks = f;
    }
    if let Some(ms) = flags.get_parsed::<f64>("cloud-rtt-ms")? {
        if ms < 0.0 {
            bail!("--cloud-rtt-ms must be >= 0");
        }
        cc.cloud_rtt_us = (ms * 1000.0).round() as u64;
    }
    if let Some(ms) = flags.get_parsed::<f64>("migration-cost-ms")? {
        if ms < 0.0 {
            bail!("--migration-cost-ms must be >= 0");
        }
        cc.migration = Some(MigrationPolicy { cost_us: (ms * 1000.0).round() as u64 });
    }
    if let Some(s) = flags.get_parsed::<u64>("controller-epoch-s")? {
        if s == 0 {
            bail!("--controller-epoch-s must be > 0");
        }
        let mut ctl = cc.controller.unwrap_or_default();
        ctl.epoch_us = s * 1_000_000;
        cc.controller = Some(ctl);
    }
    if let Some(name) = flags.get("topology") {
        let hop_ms: f64 = flags.get_parsed::<f64>("hop-ms")?.unwrap_or(1.0);
        if hop_ms < 0.0 {
            bail!("--hop-ms must be >= 0");
        }
        cc.topology = Topology::parse(name, (hop_ms * 1000.0).round() as u64).ok_or_else(
            || anyhow!("bad --topology {name:?} (flat|star|ring; matrix only via TOML)"),
        )?;
    } else if flags.has("hop-ms") {
        bail!("--hop-ms requires --topology star|ring");
    }
    if let Some(rate) = flags.get_parsed::<f64>("churn-rate")? {
        // Mean node failures per virtual hour; 0 disables churn.
        if rate < 0.0 {
            bail!("--churn-rate must be >= 0");
        }
        if rate == 0.0 {
            cc.churn = None;
        } else {
            let mut churn = cc.churn.unwrap_or_default();
            churn.mean_up_us = (3_600_000_000.0 / rate).round().max(1.0) as u64;
            cc.churn = Some(churn);
        }
    }
    if let Some(ms) = flags.get_parsed::<u64>("slo-ms")? {
        if ms == 0 {
            bail!("--slo-ms must be > 0");
        }
        let mut slo = cc.slo.unwrap_or_default();
        slo.default_slo_ms = Some(ms);
        cc.slo = Some(slo);
    }
    if let Some(s) = flags.get_parsed::<f64>("slo-fairshare-window-s")? {
        if s <= 0.0 {
            bail!("--slo-fairshare-window-s must be > 0");
        }
        let mut slo = cc.slo.unwrap_or_default();
        let mut fs = slo.fairshare.unwrap_or_default();
        fs.window_us = (s * 1e6).round() as u64;
        slo.fairshare = Some(fs);
        cc.slo = Some(slo);
    }
    if let Some(p) = flags.get_parsed::<f64>("slo-deflate-pressure")? {
        if !(p > 0.0 && p <= 1.0) {
            bail!("--slo-deflate-pressure must be in (0, 1]");
        }
        let mut slo = cc.slo.unwrap_or_default();
        let mut d = slo.deflation.unwrap_or_default();
        d.pressure = p;
        slo.deflation = Some(d);
        cc.slo = Some(slo);
    }
    if let Some(stem) = flags.get("trace") {
        cfg.workload.source = WorkloadSourceKind::Replay { trace: stem.to_string() };
    }
    if let Some(s) = flags.get("source") {
        cfg.workload.source = match s {
            "synth" => WorkloadSourceKind::Synth,
            "closed-loop" => WorkloadSourceKind::ClosedLoop,
            "replay" => match flags.get("trace") {
                Some(stem) => WorkloadSourceKind::Replay { trace: stem.to_string() },
                None => bail!("--source replay needs --trace STEM"),
            },
            other => bail!("bad --source {other:?} (synth|replay|closed-loop)"),
        };
    }
    if let Some(c) = flags.get_parsed::<usize>("clients")? {
        cfg.workload.clients = c;
    }
    if let Some(ms) = flags.get_parsed::<u64>("think-ms")? {
        cfg.workload.think_ms = ms;
    }
    if let Some(s) = flags.get_parsed::<usize>("shards")? {
        if s == 0 {
            bail!("--shards must be >= 1");
        }
        let mut sh = cc.sharding.unwrap_or_default();
        sh.shards = s;
        cc.sharding = Some(sh);
    }
    if let Some(w) = flags.get_parsed::<u64>("window-us")? {
        // 0 is legal: a flush per arrival (exact) / a barrier per
        // arrival, which is bit-for-bit sequential (approx).
        let mut sh = cc.sharding.unwrap_or_default();
        sh.window_us = w;
        cc.sharding = Some(sh);
    }
    if let Some(m) = flags.get("shard-mode") {
        let mode = ShardMode::parse(m)
            .ok_or_else(|| anyhow!("bad --shard-mode {m:?} (exact|approx)"))?;
        let mut sh = cc.sharding.unwrap_or_default();
        sh.mode = mode;
        cc.sharding = Some(sh);
    }
    cfg.cluster = Some(cc);
    cfg.validate()?;
    println!("# {}", cfg.describe());

    let mut source = cfg.build_arrival_source()?;
    // build_cluster_spec already applies the experiment-harness
    // init-occupancy convention (HoldsMemory / KISS_INIT_LATENCY_ONLY).
    let spec = cfg.build_cluster_spec();
    let sharding = cfg.sharding();
    if sharding.shards > 1 || sharding.mode == ShardMode::Approx {
        let plan = plan_sharding(&spec, source.wants_feedback(), &sharding);
        println!("# sharding: {}", plan.describe());
    }
    let r = run_cluster_sharded(source.as_mut(), &spec, &sharding);

    println!(
        "{:>10} {:>10} {:>10} {:>8} {:>9} {:>8} {:>12} {:>8} {:>10} {:>8} {:>9} {:>8}",
        "slice", "hits", "misses", "drops", "offloads", "migr", "coldstart%", "drop%",
        "offload%", "migr%", "sloOff%", "sloViol%"
    );
    for (name, c) in
        [("overall", &r.report.overall), ("small", &r.report.small), ("large", &r.report.large)]
    {
        println!(
            "{:>10} {:>10} {:>10} {:>8} {:>9} {:>8} {:>12.2} {:>8.2} {:>10.2} {:>8.2} {:>9.2} {:>8.2}",
            name,
            c.hits,
            c.misses,
            c.drops,
            c.offloads,
            c.migrations,
            c.cold_start_pct(),
            c.drop_pct(),
            c.offload_pct(),
            c.migration_pct(),
            c.slo_offload_pct(),
            c.slo_violation_pct()
        );
    }
    println!("\nlatency ms (p50/p95/p99): {}", r.report.latency().summary_ms());

    println!("\nper-node ({} invocations rerouted to fallbacks):", r.rerouted);
    for (i, node) in r.per_node.iter().enumerate() {
        println!(
            "  node {i}: hits {:>9} misses {:>8} migr {:>6} peak {:>6} MB | {}",
            node.overall.hits,
            node.overall.misses,
            node.overall.migrations,
            r.peak_used_mb[i],
            r.descriptions[i]
        );
    }
    if cfg.cluster.as_ref().is_some_and(|c| c.migration.is_some()) {
        println!(
            "\nmigration: {} containers migrated, {} rescue hits served in place",
            r.report.overall.migrations, r.rescues
        );
    }
    if cfg.cluster.as_ref().is_some_and(|c| c.controller.is_some()) {
        println!(
            "\ncontroller: {} small-node moves, {} node resplits, final router {}",
            r.small_node_moves,
            r.resplits,
            r.router.label()
        );
    }
    if cfg.cluster.as_ref().is_some_and(|c| c.churn.is_some()) {
        let live = r.live.iter().filter(|&&l| l).count();
        println!(
            "\nchurn: {} node downs / {} ups ({live}/{} live at end), \
             {} warm containers lost, {} in-flight invocations rerouted",
            r.report.node_downs,
            r.report.node_ups,
            r.live.len(),
            r.report.overall.churn_evictions,
            r.churn_reroutes
        );
    }
    if cfg.cluster.as_ref().is_some_and(|c| c.slo.is_some()) {
        println!(
            "\nslo: {:.2}% violations, {} pre-emptive cloud offloads, \
             {} containers deflated / {} reinflated",
            r.report.overall.slo_violation_pct(),
            r.report.overall.slo_offloads,
            r.deflations,
            r.reinflations
        );
    }
    Ok(())
}

fn cmd_analyze(flags: &Flags) -> Result<()> {
    let mut synth = experiments::workload::analysis_workload();
    if let Some(seed) = flags.get_parsed::<u64>("seed")? {
        synth.seed = seed;
    }
    if let Some(d) = flags.get_parsed::<u64>("duration-s")? {
        synth.duration_us = d * 1_000_000;
    }
    for f in [
        experiments::workload::fig2(&synth),
        experiments::workload::fig3(&synth),
        experiments::workload::fig4(&synth),
        experiments::workload::fig5(&synth),
    ] {
        println!("{}", f.render_text());
    }
    Ok(())
}

fn cmd_trace(flags: &Flags) -> Result<()> {
    let out = flags
        .get("out")
        .ok_or_else(|| anyhow!("--out STEM required"))?;
    let mut synth = SynthConfig::default();
    if let Some(seed) = flags.get_parsed::<u64>("seed")? {
        synth.seed = seed;
    }
    if let Some(d) = flags.get_parsed::<u64>("duration-s")? {
        synth.duration_us = d * 1_000_000;
    }
    if let Some(r) = flags.get_parsed::<f64>("rate")? {
        synth.rate_per_sec = r;
    }
    let trace = synthesize(&synth);
    loader::save(&trace, Path::new(out))?;
    println!(
        "wrote {} functions / {} events to {out}.{{functions,events}}.csv",
        trace.functions.len(),
        trace.events.len()
    );
    Ok(())
}

fn artifacts_dir(flags: &Flags) -> PathBuf {
    flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn cmd_selfcheck(flags: &Flags) -> Result<()> {
    let dir = artifacts_dir(flags);
    let mut engine = kiss_faas::runtime::Engine::cpu()?;
    println!("platform: {}", engine.platform());
    let names = engine
        .load_all(&dir)
        .with_context(|| format!("loading artifacts from {}", dir.display()))?;
    for n in &names {
        let p = engine.get(n).unwrap();
        println!(
            "  {n}: in{:?} out{:?} compile {:?} — golden OK",
            p.spec.input_shape, p.spec.output_shape, p.compile_time
        );
    }
    println!("selfcheck OK ({} payloads)", names.len());
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let dir = artifacts_dir(flags);
    let mem_gb: u64 = flags.get_parsed("mem-gb")?.unwrap_or(2);
    let port: u16 = flags.get_parsed("port")?.unwrap_or(7077);
    let cfg = SimConfig::edge_default(mem_gb * 1024);
    println!("node: {}", cfg.describe());

    // The node is built inside the server's worker thread (PJRT handles
    // are not Send). Default deployment: one small (MLP) and one large
    // (transformer) function, mirroring the paper's two classes.
    let factory_cfg = cfg.clone();
    let server = Server::start(
        move || {
            let mut node = EdgeNode::new(&factory_cfg, &dir)?;
            node.deploy(live_profile(40, SizeClass::Small), "iot_mlp_b1")?;
            node.deploy(live_profile(350, SizeClass::Large), "analytics_transformer_b1")?;
            println!("partitions: {}", node.describe());
            for f in node.functions() {
                println!("  fn {} -> {} ({} MB)", f.profile.id.0, f.payload, f.profile.mem_mb);
            }
            Ok(node)
        },
        port,
    )?;
    println!("listening on {} — protocol: INVOKE <id> <csv> | STATS | QUIT", server.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn live_profile(mem_mb: u32, class: SizeClass) -> FunctionProfile {
    FunctionProfile {
        id: FunctionId(0),
        app_id: 0,
        mem_mb,
        app_mem_mb: mem_mb,
        cold_start_us: 0,
        warm_start_us: 0,
        exec_us_mean: 0,
        class,
        slo_ms: None,
    }
}
