//! A memory-bounded warm container pool with a pluggable replacement
//! policy — the unit KiSS partitions and the baseline uses monolithically.
//!
//! Semantics (modified-FaaSCache, paper §4.1/§5.2):
//!
//! * **Hit** — an idle container of the function exists: reuse the most
//!   recently used one (best temporal locality).
//! * **Cold start (miss)** — no idle container: admit a new one, evicting
//!   idle containers per policy while capacity is exceeded.
//! * **Drop** — the invocation cannot be placed even after evicting every
//!   idle container (the rest of the pool is busy): punt to the cloud.
//!   Feasibility is checked *before* evicting, so an eventual drop never
//!   pointlessly destroys warm state.
//! * Busy containers hold memory and are never evictable.
//! * Idle (warm) containers hold memory until evicted — keep-alive is
//!   memory-pressure-driven as in FaaSCache; an optional TTL reaper
//!   ([`WarmPool::expire_idle_before`]) is provided as an extension.

use std::collections::BTreeSet;

use crate::util::fxhash::FxHashMap;

use super::container::{Container, ContainerId, ContainerState};
use super::policy::ReplacementPolicy;
use crate::trace::{FunctionId, FunctionProfile};

/// Result of [`WarmPool::try_acquire`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Acquire {
    /// Warm hit: the MRU idle container of the function was reused.
    Hit(ContainerId),
    /// Cold start: a new container was admitted (possibly after
    /// evictions) and is busy serving the invocation.
    Cold(ContainerId),
    /// Infeasible even after evicting every idle container: dropped.
    Drop,
}

/// A memory-bounded warm container pool with a pluggable replacement
/// policy — see the module docs for the hit/cold/drop semantics.
pub struct WarmPool {
    capacity_mb: u64,
    used_mb: u64,
    idle_mb: u64,
    policy: Box<dyn ReplacementPolicy>,
    containers: FxHashMap<ContainerId, Container>,
    /// Idle containers per function, ordered by (last_used_us, id) so a
    /// hit takes the most recently used instance in O(log n).
    idle_by_func: FxHashMap<FunctionId, BTreeSet<(u64, ContainerId)>>,
    next_id: u64,
    /// Lifetime eviction count (reported by benches/metrics).
    pub evictions: u64,
}

impl WarmPool {
    /// An empty pool of `capacity_mb` running the given replacement
    /// policy.
    pub fn new(capacity_mb: u64, policy: Box<dyn ReplacementPolicy>) -> Self {
        Self {
            capacity_mb,
            used_mb: 0,
            idle_mb: 0,
            policy,
            containers: FxHashMap::default(),
            idle_by_func: FxHashMap::default(),
            next_id: 0,
            evictions: 0,
        }
    }

    /// Configured capacity (MB).
    pub fn capacity_mb(&self) -> u64 {
        self.capacity_mb
    }

    /// Resident memory (MB): idle + busy containers.
    pub fn used_mb(&self) -> u64 {
        self.used_mb
    }

    /// Memory (MB) held by idle (warm, evictable) containers.
    pub fn idle_mb(&self) -> u64 {
        self.idle_mb
    }

    /// Unoccupied capacity (MB).
    pub fn free_mb(&self) -> u64 {
        // Saturating: a live resize (set_capacity_mb) may leave the pool
        // transiently over-committed by busy containers.
        self.capacity_mb.saturating_sub(self.used_mb)
    }

    /// Live-resize the pool (adaptive partitioning). Shrinking evicts idle
    /// containers per policy until the pool fits; busy containers cannot
    /// be reclaimed, so the pool may stay over-committed until they
    /// finish (drained on release / next acquire). Returns evictions.
    pub fn set_capacity_mb(&mut self, new_capacity_mb: u64) -> usize {
        self.capacity_mb = new_capacity_mb;
        self.shrink_to_fit()
    }

    /// Evict idle containers (policy order) while over capacity.
    fn shrink_to_fit(&mut self) -> usize {
        let mut evicted = 0;
        while self.used_mb > self.capacity_mb {
            let Some(victim) = self.policy.pop_victim() else { break };
            self.remove_idle(victim);
            self.evictions += 1;
            evicted += 1;
        }
        evicted
    }

    /// Number of resident containers (idle + busy).
    pub fn container_count(&self) -> usize {
        self.containers.len()
    }

    /// Number of idle (warm) containers.
    pub fn idle_count(&self) -> usize {
        self.policy.len()
    }

    /// Short name of the replacement policy (`lru`/`gd`/`freq`).
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Borrow a resident container by id, if present.
    pub fn container(&self, id: ContainerId) -> Option<&Container> {
        self.containers.get(&id)
    }

    /// Dispatch one invocation of `profile` arriving at `now_us`.
    pub fn try_acquire(&mut self, profile: &FunctionProfile, now_us: u64) -> Acquire {
        // 1. Warm hit: take the most recently used idle instance.
        if let Some(set) = self.idle_by_func.get_mut(&profile.id) {
            if let Some(&(key, id)) = set.iter().next_back() {
                set.remove(&(key, id));
                if set.is_empty() {
                    self.idle_by_func.remove(&profile.id);
                }
                self.policy.on_leave(id);
                let c = self.containers.get_mut(&id).expect("idle index desync");
                debug_assert_eq!(c.state, ContainerState::Idle);
                c.state = ContainerState::Busy;
                c.last_used_us = now_us;
                c.uses += 1;
                self.idle_mb -= c.mem_mb as u64;
                return Acquire::Hit(id);
            }
        }

        // 2-4. Cold path: feasibility check, policy evictions, born-busy
        //      admission — shared with the migration path (admit_warm).
        match self.admit_warm(profile, now_us) {
            Some(id) => Acquire::Cold(id),
            None => Acquire::Drop,
        }
    }

    /// An invocation finished; its container becomes idle (warm).
    pub fn release(&mut self, id: ContainerId, now_us: u64) {
        let c = self.containers.get_mut(&id).expect("release of unknown container");
        assert_eq!(c.state, ContainerState::Busy, "double release of {id:?}");
        c.state = ContainerState::Idle;
        self.idle_mb += c.mem_mb as u64;
        self.idle_by_func
            .entry(c.func)
            .or_default()
            .insert((c.last_used_us, id));
        self.policy.on_idle(c, now_us);
        // A live shrink may have left the pool over-committed on busy
        // containers; reclaim as they come back.
        if self.used_mb > self.capacity_mb {
            self.shrink_to_fit();
        }
    }

    /// Remove an idle container entirely (policy victim or TTL expiry).
    /// The policy's index entry must already be gone.
    fn remove_idle(&mut self, id: ContainerId) {
        let c = self.containers.remove(&id).expect("evicting unknown container");
        debug_assert_eq!(c.state, ContainerState::Idle, "evicted a busy container");
        self.used_mb -= c.mem_mb as u64;
        self.idle_mb -= c.mem_mb as u64;
        if let Some(set) = self.idle_by_func.get_mut(&c.func) {
            set.remove(&(c.last_used_us, id));
            if set.is_empty() {
                self.idle_by_func.remove(&c.func);
            }
        }
    }

    /// Whether any idle warm container of `func` is resident (a cluster
    /// migration donor candidate holds one).
    pub fn has_idle(&self, func: FunctionId) -> bool {
        self.idle_by_func.contains_key(&func)
    }

    /// Whether a busy container of `mem_mb` could be admitted right now
    /// (the cold-path feasibility check, without performing evictions):
    /// busy memory is unreclaimable, idle memory is.
    pub fn can_admit(&self, mem_mb: u32) -> bool {
        let busy_mb = self.used_mb - self.idle_mb;
        mem_mb as u64 <= self.capacity_mb.saturating_sub(busy_mb)
    }

    /// Remove and return the most-recently-used idle container of `func`
    /// (the donor side of a cross-node migration). Unlike an eviction,
    /// this does not count toward [`WarmPool::evictions`] — the warm
    /// state moves to another node instead of being destroyed.
    pub fn take_idle_mru(&mut self, func: FunctionId) -> Option<ContainerId> {
        let set = self.idle_by_func.get(&func)?;
        let &(_, id) = set.iter().next_back()?;
        self.policy.on_leave(id);
        self.remove_idle(id);
        Some(id)
    }

    /// Admit a new container of `profile`, born busy serving an
    /// invocation: feasibility is checked *before* evicting (a doomed
    /// admission never destroys warm state; busy memory is
    /// unreclaimable, idle memory is), then idle containers are evicted
    /// per policy until the container fits. Returns `None` when
    /// admission is infeasible (see [`WarmPool::can_admit`]).
    ///
    /// This is both the cold path of [`WarmPool::try_acquire`] (the
    /// container then pays its init) and the recipient side of a
    /// cross-node migration (the container arrives warm) — one shared
    /// implementation so the two admission paths can never desync.
    pub fn admit_warm(&mut self, profile: &FunctionProfile, now_us: u64) -> Option<ContainerId> {
        let needed = profile.mem_mb as u64;
        if !self.can_admit(profile.mem_mb) {
            return None;
        }
        while self.free_mb() < needed {
            let victim = self
                .policy
                .pop_victim()
                .expect("can_admit guaranteed a victim");
            self.remove_idle(victim);
            self.evictions += 1;
        }
        let id = ContainerId(self.next_id);
        self.next_id += 1;
        let c = Container::new(id, profile.id, profile.mem_mb, profile.cold_start_us, now_us);
        self.used_mb += needed;
        self.containers.insert(id, c);
        Some(id)
    }

    /// Tear down *every* resident container — the pool's node failed
    /// (churn extension). Busy containers die too (the driver retires
    /// their pending completions separately); the returned list holds the
    /// functions of the idle (warm) containers destroyed, for
    /// churn-eviction accounting. Unlike policy evictions this does not
    /// count toward [`WarmPool::evictions`] — the node, not memory
    /// pressure, killed the state. Capacity and policy configuration
    /// survive for the node's eventual recovery.
    pub fn drain_all(&mut self) -> Vec<FunctionId> {
        // Empty the policy's idle index first so it cannot dangle.
        while self.policy.pop_victim().is_some() {}
        let idle_funcs = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| c.func)
            .collect();
        self.containers.clear();
        self.idle_by_func.clear();
        self.used_mb = 0;
        self.idle_mb = 0;
        idle_funcs
    }

    /// Extension: reap idle containers whose last use is older than
    /// `cutoff_us` (fixed keep-alive TTL, as in OpenWhisk). Returns the
    /// number reaped.
    pub fn expire_idle_before(&mut self, cutoff_us: u64) -> usize {
        let stale: Vec<ContainerId> = self
            .containers
            .values()
            .filter(|c| c.is_idle() && c.last_used_us < cutoff_us)
            .map(|c| c.id)
            .collect();
        for id in &stale {
            self.policy.on_leave(*id);
            self.remove_idle(*id);
        }
        stale.len()
    }

    /// Structural invariants, used by the property suite:
    /// * used = Σ container mem; idle = Σ idle container mem
    /// * used ≤ capacity
    /// * policy index size == idle container count == per-func index size
    pub fn check_invariants(&self) -> Result<(), String> {
        let used: u64 = self.containers.values().map(|c| c.mem_mb as u64).sum();
        if used != self.used_mb {
            return Err(format!("used_mb {} != Σmem {used}", self.used_mb));
        }
        let idle: u64 = self
            .containers
            .values()
            .filter(|c| c.is_idle())
            .map(|c| c.mem_mb as u64)
            .sum();
        if idle != self.idle_mb {
            return Err(format!("idle_mb {} != Σidle {idle}", self.idle_mb));
        }
        // Over-capacity is only legal transiently after a live shrink, and
        // then only by busy (unreclaimable) memory.
        if self.used_mb > self.capacity_mb && self.idle_mb > 0 {
            return Err(format!(
                "over capacity with idle memory: used {} cap {} idle {}",
                self.used_mb, self.capacity_mb, self.idle_mb
            ));
        }
        let idle_count = self.containers.values().filter(|c| c.is_idle()).count();
        if idle_count != self.policy.len() {
            return Err(format!(
                "policy index {} != idle containers {idle_count}",
                self.policy.len()
            ));
        }
        let func_index: usize = self.idle_by_func.values().map(|s| s.len()).sum();
        if func_index != idle_count {
            return Err(format!(
                "per-func index {func_index} != idle containers {idle_count}"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::PolicyKind;
    use super::*;
    use crate::trace::SizeClass;

    fn profile(id: u32, mem_mb: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(id),
            app_id: id,
            mem_mb,
            app_mem_mb: mem_mb,
            cold_start_us: 1_000_000,
            warm_start_us: 1_000,
            exec_us_mean: 10_000,
            class: if mem_mb >= 200 { SizeClass::Large } else { SizeClass::Small },
            slo_ms: None,
        }
    }

    fn pool(cap: u64) -> WarmPool {
        WarmPool::new(cap, PolicyKind::Lru.build())
    }

    #[test]
    fn cold_then_hit_lifecycle() {
        let mut p = pool(100);
        let f = profile(0, 40);
        let Acquire::Cold(id) = p.try_acquire(&f, 0) else { panic!() };
        assert_eq!(p.used_mb(), 40);
        assert_eq!(p.idle_count(), 0);
        p.release(id, 10);
        assert_eq!(p.idle_count(), 1);
        let Acquire::Hit(id2) = p.try_acquire(&f, 20) else { panic!() };
        assert_eq!(id, id2);
        assert_eq!(p.container(id).unwrap().uses, 2);
        p.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_memory_for_new_function() {
        let mut p = pool(100);
        let a = profile(0, 60);
        let b = profile(1, 60);
        let Acquire::Cold(ca) = p.try_acquire(&a, 0) else { panic!() };
        p.release(ca, 5);
        // b needs 60, free is 40 -> must evict a's idle container.
        let Acquire::Cold(_) = p.try_acquire(&b, 10) else { panic!() };
        assert_eq!(p.evictions, 1);
        assert_eq!(p.used_mb(), 60);
        assert_eq!(p.container_count(), 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn drop_when_pool_all_busy() {
        let mut p = pool(100);
        let a = profile(0, 60);
        let b = profile(1, 60);
        let Acquire::Cold(_) = p.try_acquire(&a, 0) else { panic!() };
        // a is still busy: 60 used, 40 free, 0 idle -> b (60) cannot fit.
        assert_eq!(p.try_acquire(&b, 1), Acquire::Drop);
        // Drops must not have evicted or admitted anything.
        assert_eq!(p.used_mb(), 60);
        assert_eq!(p.evictions, 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn oversized_function_always_drops() {
        let mut p = pool(100);
        let f = profile(0, 200);
        assert_eq!(p.try_acquire(&f, 0), Acquire::Drop);
    }

    #[test]
    fn feasibility_check_avoids_wasted_evictions() {
        let mut p = pool(100);
        let a = profile(0, 30);
        let busy = profile(1, 60);
        let Acquire::Cold(ca) = p.try_acquire(&a, 0) else { panic!() };
        p.release(ca, 1);
        let Acquire::Cold(_) = p.try_acquire(&busy, 2) else { panic!() };
        // 90 used (30 idle + 60 busy), 10 free. A 50 MB function needs
        // 50 > free(10) + idle(30) = 40 -> Drop, and the idle container
        // of `a` must survive.
        let c = profile(2, 50);
        assert_eq!(p.try_acquire(&c, 3), Acquire::Drop);
        assert_eq!(p.idle_count(), 1);
        assert_eq!(p.evictions, 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn hit_takes_most_recently_used_instance() {
        let mut p = pool(200);
        let f = profile(0, 40);
        let Acquire::Cold(c1) = p.try_acquire(&f, 0) else { panic!() };
        let Acquire::Cold(c2) = p.try_acquire(&f, 1) else { panic!() };
        p.release(c1, 10);
        p.release(c2, 20);
        // c2 started later (t=1) -> its last_used is larger -> preferred.
        let Acquire::Hit(h) = p.try_acquire(&f, 30) else { panic!() };
        assert_eq!(h, c2);
    }

    #[test]
    fn multiple_evictions_until_fit() {
        let mut p = pool(100);
        for i in 0..3 {
            let f = profile(i, 30);
            let Acquire::Cold(c) = p.try_acquire(&f, i as u64) else { panic!() };
            p.release(c, i as u64 + 1);
        }
        // 90 idle; a 100MB function needs all three evicted.
        let big = profile(9, 100);
        let Acquire::Cold(_) = p.try_acquire(&big, 10) else { panic!() };
        assert_eq!(p.evictions, 3);
        assert_eq!(p.container_count(), 1);
        p.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut p = pool(100);
        let f = profile(0, 40);
        let Acquire::Cold(c) = p.try_acquire(&f, 0) else { panic!() };
        p.release(c, 1);
        p.release(c, 2);
    }

    #[test]
    fn ttl_reaper_removes_stale_idle() {
        let mut p = pool(200);
        let f = profile(0, 40);
        let g = profile(1, 40);
        let Acquire::Cold(cf) = p.try_acquire(&f, 0) else { panic!() };
        let Acquire::Cold(cg) = p.try_acquire(&g, 1_000) else { panic!() };
        p.release(cf, 10);
        p.release(cg, 1_010);
        // Reap containers last used before t=500: only f's.
        assert_eq!(p.expire_idle_before(500), 1);
        assert_eq!(p.container_count(), 1);
        assert!(p.container(cg).is_some());
        p.check_invariants().unwrap();
    }

    #[test]
    fn take_idle_mru_removes_without_counting_eviction() {
        let mut p = pool(200);
        let f = profile(0, 40);
        let Acquire::Cold(c1) = p.try_acquire(&f, 0) else { panic!() };
        let Acquire::Cold(c2) = p.try_acquire(&f, 1) else { panic!() };
        p.release(c1, 10);
        p.release(c2, 20);
        assert!(p.has_idle(FunctionId(0)));
        // MRU instance (c2, last used at t=1) leaves first.
        assert_eq!(p.take_idle_mru(FunctionId(0)), Some(c2));
        assert_eq!(p.take_idle_mru(FunctionId(0)), Some(c1));
        assert_eq!(p.take_idle_mru(FunctionId(0)), None);
        assert!(!p.has_idle(FunctionId(0)));
        assert_eq!(p.evictions, 0, "migration take is not an eviction");
        assert_eq!(p.used_mb(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn admit_warm_respects_feasibility_and_evicts_for_room() {
        let mut p = pool(100);
        let a = profile(0, 60);
        let Acquire::Cold(ca) = p.try_acquire(&a, 0) else { panic!() };
        p.release(ca, 5);
        // 60 idle; a 50 MB migrated container fits only after evicting it.
        let b = profile(1, 50);
        assert!(p.can_admit(50));
        let id = p.admit_warm(&b, 10).expect("feasible admission");
        assert_eq!(p.evictions, 1);
        assert_eq!(p.used_mb(), 50);
        assert!(!p.container(id).unwrap().is_idle(), "admitted born busy");
        // 50 busy now; another 60 MB container cannot be admitted.
        assert!(!p.can_admit(60));
        assert_eq!(p.admit_warm(&a, 20), None);
        p.release(id, 30);
        p.check_invariants().unwrap();
    }

    #[test]
    fn drain_all_wipes_idle_and_busy_state() {
        let mut p = pool(200);
        let f = profile(0, 40);
        let g = profile(1, 60);
        let Acquire::Cold(cf) = p.try_acquire(&f, 0) else { panic!() };
        let Acquire::Cold(_) = p.try_acquire(&g, 1) else { panic!() };
        p.release(cf, 10); // f idle, g still busy
        let lost = p.drain_all();
        assert_eq!(lost, vec![FunctionId(0)], "only idle warm state is reported");
        assert_eq!(p.container_count(), 0);
        assert_eq!(p.used_mb(), 0);
        assert_eq!(p.idle_count(), 0);
        assert_eq!(p.evictions, 0, "a node failure is not a policy eviction");
        // The pool keeps working after the wipe (node recovery).
        let Acquire::Cold(_) = p.try_acquire(&f, 20) else { panic!() };
        p.check_invariants().unwrap();
    }

    #[test]
    fn works_with_all_policies() {
        for kind in PolicyKind::ALL {
            let mut p = WarmPool::new(120, kind.build());
            let a = profile(0, 40);
            let b = profile(1, 40);
            let c = profile(2, 60);
            let Acquire::Cold(ca) = p.try_acquire(&a, 0) else { panic!() };
            let Acquire::Cold(cb) = p.try_acquire(&b, 1) else { panic!() };
            p.release(ca, 10);
            p.release(cb, 20);
            let Acquire::Cold(_) = p.try_acquire(&c, 30) else { panic!() };
            assert!(p.evictions >= 1, "{}", kind.label());
            p.check_invariants().unwrap();
        }
    }
}
