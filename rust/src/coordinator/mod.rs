//! The paper's contribution: KiSS — size-aware partitioned warm-pool
//! memory management — together with the unified-pool baseline it is
//! compared against.
//!
//! Structure mirrors Figure 6 of the paper:
//!
//! * [`container`] — the container model (size, state, usage stats).
//! * [`pool`] — a memory-bounded warm pool with a pluggable
//!   [`policy::ReplacementPolicy`] (LRU / GreedyDual / Freq).
//! * [`analyzer`] — the *online* workload analyzer: O(1) EWMA profiles of
//!   invocation frequency & footprint per function, feeding placement.
//! * [`balancer`] — the load balancer implementing the KiSS partitioning
//!   logic (size threshold → pool) and the baseline (single pool).
//!
//! The [`Dispatcher`] trait is what the simulator ([`crate::sim`]) and the
//! live serving path ([`crate::serve`]) drive; both KiSS and the baseline
//! are `Dispatcher`s, so every experiment isolates exactly the policy
//! difference the paper studies.

pub mod adaptive;
pub mod analyzer;
pub mod balancer;
pub mod container;
pub mod policy;
pub mod pool;

pub use adaptive::{AdaptiveBalancer, AdaptiveConfig};
pub use balancer::{Balancer, PartitionSpec};
pub use container::{Container, ContainerId, ContainerState};
pub use pool::WarmPool;

use crate::trace::{FunctionProfile, SizeClass};

/// Result of dispatching one invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Warm container reused.
    Hit {
        pool: usize,
        container: ContainerId,
    },
    /// Cold start: a new container was admitted (possibly after evictions).
    Cold {
        pool: usize,
        container: ContainerId,
    },
    /// No capacity: the invocation is punted to the cloud.
    Drop,
}

impl Outcome {
    pub fn is_drop(&self) -> bool {
        matches!(self, Outcome::Drop)
    }

    pub fn is_hit(&self) -> bool {
        matches!(self, Outcome::Hit { .. })
    }

    pub fn is_cold(&self) -> bool {
        matches!(self, Outcome::Cold { .. })
    }
}

/// A warm-pool coordinator the simulator / server can drive.
///
/// Lifecycle per invocation: the driver first releases every container
/// whose execution finished before the arrival time (`release`), then
/// calls `dispatch`. On `Hit`/`Cold` the driver schedules a completion and
/// later calls `release` with the returned handle.
pub trait Dispatcher {
    /// Route one invocation arriving at `now_us`. Never blocks.
    fn dispatch(&mut self, profile: &FunctionProfile, now_us: u64) -> Outcome;

    /// A previously-dispatched invocation finished; its container becomes
    /// idle (warm) again.
    fn release(&mut self, pool: usize, container: ContainerId, now_us: u64);

    /// Total and per-pool occupancy, for invariant checks and gauges:
    /// `(used_mb, capacity_mb)` per pool.
    fn occupancy(&self) -> Vec<(u64, u64)>;

    /// Total resident memory (MB) across pools. Called on the simulator
    /// hot path once per event, so implementations MUST be allocation-free
    /// — sum pool occupancy directly instead of going through
    /// [`Dispatcher::occupancy`] (a former default impl did exactly that,
    /// building a `Vec` per event; see EXPERIMENTS.md §Perf: ~15% of
    /// end-to-end throughput). Required, so new dispatchers cannot
    /// silently inherit the allocating path.
    fn used_mb(&self) -> u64;

    /// Human-readable policy/partition description (reports & logs).
    fn describe(&self) -> String;

    /// Which pool this profile would route to (stable; used by metrics).
    fn route(&self, profile: &FunctionProfile) -> usize;
}

/// Classify a function against a size threshold — the KiSS router's core
/// decision (functions at or above the threshold are "large").
pub fn classify(profile: &FunctionProfile, threshold_mb: u32) -> SizeClass {
    if profile.mem_mb >= threshold_mb {
        SizeClass::Large
    } else {
        SizeClass::Small
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::FunctionId;

    fn profile(mem_mb: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(0),
            app_id: 0,
            mem_mb,
            app_mem_mb: mem_mb,
            cold_start_us: 1_000_000,
            warm_start_us: 1_000,
            exec_us_mean: 10_000,
            class: SizeClass::Small,
        }
    }

    #[test]
    fn classify_threshold_boundary() {
        assert_eq!(classify(&profile(199), 200), SizeClass::Small);
        assert_eq!(classify(&profile(200), 200), SizeClass::Large);
        assert_eq!(classify(&profile(201), 200), SizeClass::Large);
    }

    #[test]
    fn outcome_predicates() {
        assert!(Outcome::Drop.is_drop());
        assert!(Outcome::Hit { pool: 0, container: ContainerId(1) }.is_hit());
        assert!(Outcome::Cold { pool: 1, container: ContainerId(2) }.is_cold());
        assert!(!Outcome::Drop.is_hit());
    }
}
