//! The paper's contribution: KiSS — size-aware partitioned warm-pool
//! memory management — together with the unified-pool baseline it is
//! compared against.
//!
//! Structure mirrors Figure 6 of the paper:
//!
//! * [`container`] — the container model (size, state, usage stats).
//! * [`pool`] — a memory-bounded warm pool with a pluggable
//!   [`policy::ReplacementPolicy`] (LRU / GreedyDual / Freq).
//! * [`analyzer`] — the *online* workload analyzer: O(1) EWMA profiles of
//!   invocation frequency & footprint per function, feeding placement.
//! * [`balancer`] — the load balancer implementing the KiSS partitioning
//!   logic (size threshold → pool) and the baseline (single pool).
//!
//! The [`Dispatcher`] trait is what the simulator ([`crate::sim`]) and the
//! live serving path ([`crate::serve`]) drive; both KiSS and the baseline
//! are `Dispatcher`s, so every experiment isolates exactly the policy
//! difference the paper studies.

// Every submodule is `missing_docs`-clean (enforced by the crate-level
// `#![warn(missing_docs)]` and CI's `RUSTDOCFLAGS=-D warnings` gate).
pub mod adaptive;
pub mod analyzer;
pub mod balancer;
pub mod container;
pub mod policy;
pub mod pool;

pub use adaptive::{AdaptiveBalancer, AdaptiveConfig};
pub use balancer::{Balancer, PartitionSpec};
pub use container::{Container, ContainerId, ContainerState};
pub use pool::WarmPool;

use crate::trace::{FunctionProfile, SizeClass};

/// Result of dispatching one invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Warm container reused.
    Hit {
        /// Pool index the container lives in.
        pool: usize,
        /// Handle to release when the invocation completes.
        container: ContainerId,
    },
    /// Cold start: a new container was admitted (possibly after evictions).
    Cold {
        /// Pool index the container was admitted into.
        pool: usize,
        /// Handle to release when the invocation completes.
        container: ContainerId,
    },
    /// No capacity: the invocation is punted to the cloud.
    Drop,
}

impl Outcome {
    /// Whether this is a [`Outcome::Drop`].
    pub fn is_drop(&self) -> bool {
        matches!(self, Outcome::Drop)
    }

    /// Whether this is a warm [`Outcome::Hit`].
    pub fn is_hit(&self) -> bool {
        matches!(self, Outcome::Hit { .. })
    }

    /// Whether this is a [`Outcome::Cold`] start.
    pub fn is_cold(&self) -> bool {
        matches!(self, Outcome::Cold { .. })
    }
}

/// A warm-pool coordinator the simulator / server can drive.
///
/// Lifecycle per invocation: the driver first releases every container
/// whose execution finished before the arrival time (`release`), then
/// calls `dispatch`. On `Hit`/`Cold` the driver schedules a completion and
/// later calls `release` with the returned handle.
pub trait Dispatcher {
    /// Route one invocation arriving at `now_us`. Never blocks.
    fn dispatch(&mut self, profile: &FunctionProfile, now_us: u64) -> Outcome;

    /// A previously-dispatched invocation finished; its container becomes
    /// idle (warm) again.
    fn release(&mut self, pool: usize, container: ContainerId, now_us: u64);

    /// Total and per-pool occupancy, for invariant checks and gauges:
    /// `(used_mb, capacity_mb)` per pool.
    fn occupancy(&self) -> Vec<(u64, u64)>;

    /// Total resident memory (MB) across pools. Called on the simulator
    /// hot path once per event, so implementations MUST be allocation-free
    /// — sum pool occupancy directly instead of going through
    /// [`Dispatcher::occupancy`] (a former default impl did exactly that,
    /// building a `Vec` per event; see EXPERIMENTS.md §Perf: ~15% of
    /// end-to-end throughput). Required, so new dispatchers cannot
    /// silently inherit the allocating path.
    fn used_mb(&self) -> u64;

    /// Human-readable policy/partition description (reports & logs).
    fn describe(&self) -> String;

    /// Which pool this profile would route to (stable; used by metrics).
    fn route(&self, profile: &FunctionProfile) -> usize;

    // --- Cross-node migration hooks (cluster extension) ---------------
    //
    // The cluster engine uses these to move an idle warm container from
    // a donor node to a recipient node when placement would otherwise
    // fail. Every method has an opt-out default, so dispatchers that do
    // not participate in migration (e.g. the live serving node) need no
    // changes.

    /// Whether an idle warm container of `profile`'s function is resident
    /// (this node could donate one to a migration). Default: no.
    fn has_idle(&self, profile: &FunctionProfile) -> bool {
        let _ = profile;
        false
    }

    /// Remove the most-recently-used idle warm container of `profile`'s
    /// function (the donor side of a migration). Returns whether one was
    /// removed. Default: never donates.
    fn take_idle(&mut self, profile: &FunctionProfile) -> bool {
        let _ = profile;
        false
    }

    /// Whether a busy container of `profile` could be admitted into its
    /// routed pool right now (busy memory is unreclaimable; idle memory
    /// counts as evictable headroom). Default: no.
    fn can_admit(&self, profile: &FunctionProfile) -> bool {
        let _ = profile;
        false
    }

    /// Admit a migrated warm container, born busy serving the triggering
    /// invocation (the recipient side of a migration); evicts idle
    /// containers per policy to make room. Returns the `(pool, container)`
    /// handle the driver later passes to [`Dispatcher::release`], or
    /// `None` when admission is infeasible. Default: never admits.
    fn admit_migrated(
        &mut self,
        profile: &FunctionProfile,
        now_us: u64,
    ) -> Option<(usize, ContainerId)> {
        let _ = (profile, now_us);
        None
    }

    // --- Churn hook (cluster extension) -------------------------------

    /// The node failed: tear down every resident container (busy ones
    /// included — the cluster driver separately retires their pending
    /// completions) and return the functions of the *idle* (warm)
    /// containers destroyed, so the driver can account the lost warm
    /// state ([`crate::metrics::Counters::churn_evictions`]). The
    /// dispatcher keeps its configuration (partition split, analyzer
    /// state) — only container state dies with the node. Default: nothing
    /// resident, nothing to do.
    fn evict_all(&mut self) -> Vec<crate::trace::FunctionId> {
        Vec::new()
    }

    // --- Online-controller hooks (cluster extension) ------------------

    /// Current small-pool share of a two-pool KiSS dispatcher, or `None`
    /// when this dispatcher has no externally adjustable split (baseline
    /// single pool, self-managing adaptive node, N-way partitions).
    fn small_frac(&self) -> Option<f64> {
        None
    }

    /// Ask the dispatcher to live-resize its small/large split to
    /// `small_frac` (the cluster controller's per-node lever). Returns
    /// whether the resize was applied. Default: refuses — only two-pool
    /// KiSS balancers are externally resizable.
    fn try_set_split(&mut self, small_frac: f64) -> bool {
        let _ = small_frac;
        false
    }
}

/// Classify a function against a size threshold — the KiSS router's core
/// decision (functions at or above the threshold are "large").
pub fn classify(profile: &FunctionProfile, threshold_mb: u32) -> SizeClass {
    if profile.mem_mb >= threshold_mb {
        SizeClass::Large
    } else {
        SizeClass::Small
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::FunctionId;

    fn profile(mem_mb: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(0),
            app_id: 0,
            mem_mb,
            app_mem_mb: mem_mb,
            cold_start_us: 1_000_000,
            warm_start_us: 1_000,
            exec_us_mean: 10_000,
            class: SizeClass::Small,
            slo_ms: None,
        }
    }

    #[test]
    fn classify_threshold_boundary() {
        assert_eq!(classify(&profile(199), 200), SizeClass::Small);
        assert_eq!(classify(&profile(200), 200), SizeClass::Large);
        assert_eq!(classify(&profile(201), 200), SizeClass::Large);
    }

    #[test]
    fn outcome_predicates() {
        assert!(Outcome::Drop.is_drop());
        assert!(Outcome::Hit { pool: 0, container: ContainerId(1) }.is_hit());
        assert!(Outcome::Cold { pool: 1, container: ContainerId(2) }.is_cold());
        assert!(!Outcome::Drop.is_hit());
    }
}
