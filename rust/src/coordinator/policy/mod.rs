//! Warm-pool replacement policies (paper §4.5): LRU, GreedyDual
//! (FaaSCache's GDSF variant), and Frequency-based.
//!
//! A policy maintains an ordered index over the pool's *idle* containers
//! and answers "who should be evicted next" in O(log n). The pool keeps
//! the policy in sync: `on_idle` when a container becomes evictable,
//! `on_leave` when it stops being evictable (reused or evicted), and
//! `pop_victim` to select + remove the best candidate.
//!
//! Policies are deliberately oblivious to which pool they serve — the
//! KiSS result (paper §6.4 "Policy Independence") is that the *partition*,
//! not the policy, carries the benefit; the experiment suite swaps these
//! implementations freely to reproduce Figures 14–16.

mod freq;
mod greedy_dual;
mod lru;

pub use freq::Freq;
pub use greedy_dual::GreedyDual;
pub use lru::Lru;

use super::container::{Container, ContainerId};

/// Replacement policy over idle containers. See module docs for the
/// synchronization contract.
pub trait ReplacementPolicy: Send {
    /// `c` became idle (warm, evictable). The policy may mutate
    /// policy-owned fields on the container (e.g. its GD priority).
    fn on_idle(&mut self, c: &mut Container, now_us: u64);

    /// `c` left the idle set without being evicted (it was reused).
    fn on_leave(&mut self, id: ContainerId);

    /// Select and remove the best eviction victim, if any.
    fn pop_victim(&mut self) -> Option<ContainerId>;

    /// Number of idle containers currently indexed (for invariants).
    fn len(&self) -> usize;

    /// Whether no idle container is indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Short policy name (`lru`/`gd`/`freq`), used in reports.
    fn name(&self) -> &'static str;
}

/// Policy selector used by configs / CLI flags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Least-recently-used ([`Lru`]) — the paper's default.
    Lru,
    /// GreedyDual / GDSF ([`GreedyDual`]) — FaaSCache's cost-size-aware
    /// policy.
    GreedyDual,
    /// Least-frequently-used ([`Freq`]).
    Freq,
}

impl PolicyKind {
    /// Instantiate the selected policy.
    pub fn build(self) -> Box<dyn ReplacementPolicy> {
        match self {
            PolicyKind::Lru => Box::new(Lru::new()),
            PolicyKind::GreedyDual => Box::new(GreedyDual::new()),
            PolicyKind::Freq => Box::new(Freq::new()),
        }
    }

    /// Short name (`lru`/`gd`/`freq`), matching [`PolicyKind::parse`].
    pub fn label(self) -> &'static str {
        match self {
            PolicyKind::Lru => "lru",
            PolicyKind::GreedyDual => "gd",
            PolicyKind::Freq => "freq",
        }
    }

    /// Parse a policy name (case-insensitive; accepts the `label` forms
    /// plus `greedydual`/`greedy-dual`/`frequency`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(PolicyKind::Lru),
            "gd" | "greedydual" | "greedy-dual" => Some(PolicyKind::GreedyDual),
            "freq" | "frequency" => Some(PolicyKind::Freq),
            _ => None,
        }
    }

    /// Every selectable policy, in experiment-sweep order.
    pub const ALL: [PolicyKind; 3] =
        [PolicyKind::Lru, PolicyKind::GreedyDual, PolicyKind::Freq];
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::trace::FunctionId;

    pub fn mk(id: u64, func: u32, mem: u32, cold_us: u64) -> Container {
        let mut c = Container::new(
            ContainerId(id),
            FunctionId(func),
            mem,
            cold_us,
            0,
        );
        c.state = super::super::container::ContainerState::Idle;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_and_label_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.label()), Some(k));
        }
        assert_eq!(PolicyKind::parse("GreedyDual"), Some(PolicyKind::GreedyDual));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn build_produces_named_policies() {
        assert_eq!(PolicyKind::Lru.build().name(), "lru");
        assert_eq!(PolicyKind::GreedyDual.build().name(), "gd");
        assert_eq!(PolicyKind::Freq.build().name(), "freq");
    }
}
