//! Least-Recently-Used replacement — the paper's default policy for both
//! the baseline and the partitioned pools.

use std::collections::BTreeSet;

use crate::util::fxhash::FxHashMap;

use super::super::container::{Container, ContainerId};
use super::ReplacementPolicy;

/// LRU over idle containers: victim = smallest `last_used_us`.
///
/// Index: `BTreeSet<(last_used_us, id)>` + reverse map for O(log n)
/// removal. Ties break on container id, which is allocation order —
/// deterministic.
#[derive(Debug, Default)]
pub struct Lru {
    order: BTreeSet<(u64, ContainerId)>,
    key_of: FxHashMap<ContainerId, u64>,
}

impl Lru {
    /// An empty LRU index.
    pub fn new() -> Self {
        Self::default()
    }
}

impl ReplacementPolicy for Lru {
    fn on_idle(&mut self, c: &mut Container, _now_us: u64) {
        // last_used_us was stamped by the pool when the container started
        // its most recent invocation.
        let prev = self.key_of.insert(c.id, c.last_used_us);
        debug_assert!(prev.is_none(), "container {c:?} already idle");
        self.order.insert((c.last_used_us, c.id));
    }

    fn on_leave(&mut self, id: ContainerId) {
        if let Some(key) = self.key_of.remove(&id) {
            let removed = self.order.remove(&(key, id));
            debug_assert!(removed);
        }
    }

    fn pop_victim(&mut self) -> Option<ContainerId> {
        let &(key, id) = self.order.iter().next()?;
        self.order.remove(&(key, id));
        self.key_of.remove(&id);
        Some(id)
    }

    fn len(&self) -> usize {
        self.order.len()
    }

    fn name(&self) -> &'static str {
        "lru"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::mk;
    use super::*;

    #[test]
    fn evicts_least_recently_used_first() {
        let mut p = Lru::new();
        let mut a = mk(1, 0, 40, 1000);
        let mut b = mk(2, 1, 40, 1000);
        let mut c = mk(3, 2, 40, 1000);
        a.last_used_us = 300;
        b.last_used_us = 100;
        c.last_used_us = 200;
        p.on_idle(&mut a, 300);
        p.on_idle(&mut b, 300);
        p.on_idle(&mut c, 300);
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
        assert_eq!(p.pop_victim(), Some(ContainerId(3)));
        assert_eq!(p.pop_victim(), Some(ContainerId(1)));
        assert_eq!(p.pop_victim(), None);
    }

    #[test]
    fn leave_removes_from_order() {
        let mut p = Lru::new();
        let mut a = mk(1, 0, 40, 1000);
        let mut b = mk(2, 1, 40, 1000);
        a.last_used_us = 1;
        b.last_used_us = 2;
        p.on_idle(&mut a, 2);
        p.on_idle(&mut b, 2);
        p.on_leave(ContainerId(1)); // reused -> not evictable
        assert_eq!(p.len(), 1);
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
    }

    #[test]
    fn reinsertion_after_reuse_updates_recency() {
        let mut p = Lru::new();
        let mut a = mk(1, 0, 40, 1000);
        let mut b = mk(2, 1, 40, 1000);
        a.last_used_us = 10;
        b.last_used_us = 20;
        p.on_idle(&mut a, 20);
        p.on_idle(&mut b, 20);
        // a is reused at t=50, becomes idle again later
        p.on_leave(ContainerId(1));
        a.last_used_us = 50;
        p.on_idle(&mut a, 60);
        // now b is the LRU victim
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
    }

    #[test]
    fn tie_breaks_deterministically_by_id() {
        let mut p = Lru::new();
        let mut a = mk(7, 0, 40, 1000);
        let mut b = mk(3, 1, 40, 1000);
        a.last_used_us = 100;
        b.last_used_us = 100;
        p.on_idle(&mut a, 100);
        p.on_idle(&mut b, 100);
        assert_eq!(p.pop_victim(), Some(ContainerId(3)));
    }

    #[test]
    fn leave_unknown_id_is_noop() {
        let mut p = Lru::new();
        p.on_leave(ContainerId(99));
        assert_eq!(p.len(), 0);
    }
}
