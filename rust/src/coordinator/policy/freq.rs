//! Frequency-based replacement (paper §4.5): evict the least-frequently
//! used idle container, irrespective of size or cost. Ties break on
//! recency (older last-use evicted first), then id.

use std::collections::BTreeSet;

use crate::util::fxhash::FxHashMap;

use super::super::container::{Container, ContainerId};
use super::ReplacementPolicy;

type Key = (u64, u64); // (uses, last_used_us)

/// Frequency-based policy: victim = smallest `(uses, last_used_us)`.
#[derive(Debug, Default)]
pub struct Freq {
    order: BTreeSet<(Key, ContainerId)>,
    key_of: FxHashMap<ContainerId, Key>,
}

impl Freq {
    /// An empty frequency index.
    pub fn new() -> Self {
        Self::default()
    }
}

impl ReplacementPolicy for Freq {
    fn on_idle(&mut self, c: &mut Container, _now_us: u64) {
        let key = (c.uses, c.last_used_us);
        let prev = self.key_of.insert(c.id, key);
        debug_assert!(prev.is_none());
        self.order.insert((key, c.id));
    }

    fn on_leave(&mut self, id: ContainerId) {
        if let Some(key) = self.key_of.remove(&id) {
            let removed = self.order.remove(&(key, id));
            debug_assert!(removed);
        }
    }

    fn pop_victim(&mut self) -> Option<ContainerId> {
        let &(key, id) = self.order.iter().next()?;
        self.order.remove(&(key, id));
        self.key_of.remove(&id);
        Some(id)
    }

    fn len(&self) -> usize {
        self.order.len()
    }

    fn name(&self) -> &'static str {
        "freq"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::mk;
    use super::*;

    #[test]
    fn evicts_least_frequent_first() {
        let mut p = Freq::new();
        let mut hot = mk(1, 0, 40, 1000);
        hot.uses = 100;
        let mut warm = mk(2, 1, 40, 1000);
        warm.uses = 10;
        let mut cold = mk(3, 2, 40, 1000);
        cold.uses = 1;
        p.on_idle(&mut hot, 0);
        p.on_idle(&mut warm, 0);
        p.on_idle(&mut cold, 0);
        assert_eq!(p.pop_victim(), Some(ContainerId(3)));
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
        assert_eq!(p.pop_victim(), Some(ContainerId(1)));
    }

    #[test]
    fn equal_frequency_ties_break_on_recency() {
        let mut p = Freq::new();
        let mut a = mk(1, 0, 40, 1000);
        a.uses = 5;
        a.last_used_us = 200; // newer
        let mut b = mk(2, 1, 40, 1000);
        b.uses = 5;
        b.last_used_us = 100; // older -> evicted first
        p.on_idle(&mut a, 200);
        p.on_idle(&mut b, 200);
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
    }

    #[test]
    fn size_is_ignored() {
        let mut p = Freq::new();
        let mut big_hot = mk(1, 0, 400, 1000);
        big_hot.uses = 9;
        let mut small_cold = mk(2, 1, 30, 1000);
        small_cold.uses = 2;
        p.on_idle(&mut big_hot, 0);
        p.on_idle(&mut small_cold, 0);
        // Freq keeps the frequent container even though it is 13x bigger.
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
    }
}
