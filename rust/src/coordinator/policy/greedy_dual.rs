//! Greedy-Dual replacement, FaaSCache's GDSF variant (Fuerst & Sharma,
//! ASPLOS'21) — the paper's "GD" policy (§4.5).
//!
//! Each idle container gets a priority
//!
//! ```text
//!   priority = clock + freq * cost / size
//! ```
//!
//! where `freq` is the container's use count, `cost` the function's
//! cold-start latency (what a miss would pay), and `size` its memory
//! footprint. The victim is the minimum-priority container; on eviction
//! the pool-global `clock` inflates to the victim's priority, aging out
//! stale high-priority entries.

use std::collections::BTreeSet;

use crate::util::fxhash::FxHashMap;

use super::super::container::{Container, ContainerId};
use super::ReplacementPolicy;

/// Total order over f64 priorities: positive finite floats compare by bit
/// pattern, which lets us keep a BTreeSet index without OrderedFloat.
fn key_bits(p: f64) -> u64 {
    debug_assert!(p.is_finite() && p >= 0.0, "GD priority must be >= 0, got {p}");
    p.to_bits()
}

/// GDSF policy state: a priority index plus the aging clock.
#[derive(Debug, Default)]
pub struct GreedyDual {
    clock: f64,
    order: BTreeSet<(u64, ContainerId)>,
    key_of: FxHashMap<ContainerId, u64>,
}

impl GreedyDual {
    /// An empty GDSF index with the clock at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current clock (inflation) value — exposed for tests/metrics.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    fn priority(&self, c: &Container) -> f64 {
        // cost in milliseconds keeps magnitudes comparable to FaaSCache's
        // formulation; size in MB.
        let cost_ms = c.cold_cost_us as f64 / 1e3;
        self.clock + (c.uses as f64) * cost_ms / (c.mem_mb.max(1) as f64)
    }
}

impl ReplacementPolicy for GreedyDual {
    fn on_idle(&mut self, c: &mut Container, _now_us: u64) {
        let p = self.priority(c);
        c.gd_priority = p;
        let bits = key_bits(p);
        let prev = self.key_of.insert(c.id, bits);
        debug_assert!(prev.is_none());
        self.order.insert((bits, c.id));
    }

    fn on_leave(&mut self, id: ContainerId) {
        if let Some(bits) = self.key_of.remove(&id) {
            let removed = self.order.remove(&(bits, id));
            debug_assert!(removed);
        }
    }

    fn pop_victim(&mut self) -> Option<ContainerId> {
        let &(bits, id) = self.order.iter().next()?;
        self.order.remove(&(bits, id));
        self.key_of.remove(&id);
        // Clock inflation: future priorities start from the evicted one.
        self.clock = f64::from_bits(bits);
        Some(id)
    }

    fn len(&self) -> usize {
        self.order.len()
    }

    fn name(&self) -> &'static str {
        "gd"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::mk;
    use super::*;

    #[test]
    fn prefers_evicting_cheap_large_containers() {
        let mut p = GreedyDual::new();
        // a: small+expensive cold start -> high priority (keep)
        let mut a = mk(1, 0, 40, 10_000_000);
        // b: large+cheap cold start -> low priority (evict)
        let mut b = mk(2, 1, 400, 1_000_000);
        p.on_idle(&mut a, 0);
        p.on_idle(&mut b, 0);
        assert!(a.gd_priority > b.gd_priority);
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
    }

    #[test]
    fn frequency_raises_priority() {
        let mut p = GreedyDual::new();
        let mut hot = mk(1, 0, 40, 1_000_000);
        hot.uses = 50;
        let mut cold = mk(2, 1, 40, 1_000_000);
        cold.uses = 1;
        p.on_idle(&mut hot, 0);
        p.on_idle(&mut cold, 0);
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
    }

    #[test]
    fn clock_inflates_on_eviction() {
        let mut p = GreedyDual::new();
        let mut a = mk(1, 0, 100, 2_000_000);
        p.on_idle(&mut a, 0);
        assert_eq!(p.clock(), 0.0);
        p.pop_victim();
        assert!(p.clock() > 0.0, "clock should inflate to victim priority");
        // A new identical container now gets a higher priority than the
        // first one had (aging).
        let mut b = mk(2, 0, 100, 2_000_000);
        p.on_idle(&mut b, 0);
        assert!(b.gd_priority > a.gd_priority);
    }

    #[test]
    fn leave_then_victim_skips_left_container() {
        let mut p = GreedyDual::new();
        let mut a = mk(1, 0, 400, 1_000_000); // lowest priority
        let mut b = mk(2, 1, 40, 5_000_000);
        p.on_idle(&mut a, 0);
        p.on_idle(&mut b, 0);
        p.on_leave(ContainerId(1));
        assert_eq!(p.pop_victim(), Some(ContainerId(2)));
        assert_eq!(p.pop_victim(), None);
    }

    #[test]
    fn key_bits_monotonic_for_positive_floats() {
        let xs = [0.0, 0.5, 1.0, 1.5, 10.0, 1e9];
        for w in xs.windows(2) {
            assert!(key_bits(w[0]) < key_bits(w[1]));
        }
    }
}
