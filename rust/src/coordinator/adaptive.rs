//! Adaptive partitioning — the paper's §7.3 future-work direction,
//! implemented as an extension: *"Adaptive partitioning informed by
//! real-time workload monitoring could address the observed trade-offs in
//! very low memory ranges."*
//!
//! [`AdaptiveBalancer`] wraps a two-pool KiSS [`Balancer`] and
//! periodically rebalances the small/large split from observed pressure:
//! every `interval_us` of virtual time it compares the two pools'
//! *rejection pressure* (drops + evictions per admitted MB) over the last
//! window and shifts `step` of capacity toward the more-pressured pool,
//! clamped to `[min_frac, max_frac]`.
//!
//! Rebalancing is a *live resize* ([`Balancer::set_split`]): the growing
//! pool keeps all warm state, the shrinking pool evicts idle containers
//! per its policy, and busy containers are never disturbed (the pool may
//! stay transiently over-committed until they finish). The ablation bench
//! compares static 80-20 vs adaptive at the paper's problematic 2–3 GB
//! sizes.

use super::balancer::Balancer;
use super::container::ContainerId;
use super::policy::PolicyKind;
use super::{Dispatcher, Outcome};
use crate::trace::FunctionProfile;

/// Rebalancing configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Initial small-pool share.
    pub initial_frac: f64,
    /// Size threshold (MB) separating the classes.
    pub threshold_mb: u32,
    /// Virtual time between rebalance decisions (µs).
    pub interval_us: u64,
    /// Capacity shifted per decision (fraction of node memory).
    pub step: f64,
    /// Lower clamp for the small-pool share.
    pub min_frac: f64,
    /// Upper clamp for the small-pool share.
    pub max_frac: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            initial_frac: crate::config::DEFAULT_SMALL_FRAC,
            threshold_mb: crate::config::DEFAULT_THRESHOLD_MB,
            interval_us: 60_000_000, // rebalance each virtual minute
            step: 0.05,
            min_frac: 0.5,
            max_frac: 0.95,
        }
    }
}

/// Per-window pressure counters for one pool.
#[derive(Clone, Copy, Debug, Default)]
struct Pressure {
    drops: u64,
    accesses: u64,
}

impl Pressure {
    fn drop_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.drops as f64 / self.accesses as f64
        }
    }
}

/// KiSS with a dynamically adjusted split.
pub struct AdaptiveBalancer {
    inner: Balancer,
    cfg: AdaptiveConfig,
    /// Current small-pool share (moves as the node rebalances).
    pub small_frac: f64,
    window: [Pressure; 2],
    next_decision_us: u64,
    /// Number of rebalances performed (observability).
    pub rebalances: u64,
    /// Hill-climbing state: the move applied last window (delta) and the
    /// combined drop rate observed *before* it, so a move that made
    /// things worse is reverted (and that direction put on cooldown).
    last_move: Option<(f64, f64)>,
    cooldown: [u32; 2], // windows to avoid moving toward [small, large]
}

impl AdaptiveBalancer {
    /// An adaptive KiSS node of `total_mb`, starting at
    /// `cfg.initial_frac` and rebalancing every `cfg.interval_us`.
    pub fn new(
        total_mb: u64,
        cfg: AdaptiveConfig,
        small_policy: PolicyKind,
        large_policy: PolicyKind,
    ) -> Self {
        let inner = Balancer::kiss(
            total_mb,
            cfg.initial_frac,
            cfg.threshold_mb,
            small_policy,
            large_policy,
        );
        Self {
            inner,
            cfg,
            small_frac: cfg.initial_frac,
            window: [Pressure::default(); 2],
            next_decision_us: cfg.interval_us,
            rebalances: 0,
            last_move: None,
            cooldown: [0; 2],
        }
    }

    /// Borrow the wrapped two-pool KiSS balancer (inspection).
    pub fn inner(&self) -> &Balancer {
        &self.inner
    }

    /// Decide and (maybe) apply a rebalance at virtual time `now_us`.
    fn maybe_rebalance(&mut self, now_us: u64) {
        if now_us < self.next_decision_us {
            return;
        }
        self.next_decision_us = now_us + self.cfg.interval_us;
        let small_p = self.window[0].drop_rate();
        let large_p = self.window[1].drop_rate();
        let total = Pressure {
            drops: self.window[0].drops + self.window[1].drops,
            accesses: self.window[0].accesses + self.window[1].accesses,
        };
        let combined = total.drop_rate();
        self.window = [Pressure::default(); 2];
        for c in &mut self.cooldown {
            *c = c.saturating_sub(1);
        }

        // Hill-climbing guard: revert a move that increased combined drops
        // and put its direction on cooldown.
        if let Some((delta, before)) = self.last_move.take() {
            if combined > before + 0.005 {
                let reverted = (self.small_frac - delta)
                    .clamp(self.cfg.min_frac, self.cfg.max_frac);
                self.cooldown[usize::from(delta < 0.0)] = 4;
                self.small_frac = reverted;
                self.inner.set_split(reverted);
                self.rebalances += 1;
                return;
            }
        }

        let delta = if large_p > small_p * 1.5 && large_p > 0.01 && self.cooldown[1] == 0 {
            -self.cfg.step // large pool is starving: give it capacity
        } else if small_p > large_p * 1.5 && small_p > 0.01 && self.cooldown[0] == 0 {
            self.cfg.step
        } else {
            return;
        };
        let new_frac = (self.small_frac + delta)
            .clamp(self.cfg.min_frac, self.cfg.max_frac);
        if (new_frac - self.small_frac).abs() < 1e-9 {
            return;
        }
        self.small_frac = new_frac;
        self.inner.set_split(new_frac);
        self.rebalances += 1;
        self.last_move = Some((delta, combined));
    }
}

impl Dispatcher for AdaptiveBalancer {
    fn dispatch(&mut self, profile: &FunctionProfile, now_us: u64) -> Outcome {
        self.maybe_rebalance(now_us);
        let pool = self.inner.route(profile);
        let outcome = self.inner.dispatch(profile, now_us);
        let w = &mut self.window[pool.min(1)];
        w.accesses += 1;
        if outcome.is_drop() {
            w.drops += 1;
        }
        outcome
    }

    fn release(&mut self, pool: usize, container: ContainerId, now_us: u64) {
        self.inner.release(pool, container, now_us);
    }

    fn occupancy(&self) -> Vec<(u64, u64)> {
        self.inner.occupancy()
    }

    fn used_mb(&self) -> u64 {
        self.inner.used_mb()
    }

    fn describe(&self) -> String {
        format!(
            "adaptive[{:.0}-{:.0}, {} rebalances] {}",
            self.small_frac * 100.0,
            (1.0 - self.small_frac) * 100.0,
            self.rebalances,
            self.inner.describe()
        )
    }

    fn route(&self, profile: &FunctionProfile) -> usize {
        self.inner.route(profile)
    }

    fn has_idle(&self, profile: &FunctionProfile) -> bool {
        self.inner.has_idle(profile)
    }

    fn take_idle(&mut self, profile: &FunctionProfile) -> bool {
        self.inner.take_idle(profile)
    }

    fn can_admit(&self, profile: &FunctionProfile) -> bool {
        self.inner.can_admit(profile)
    }

    fn admit_migrated(
        &mut self,
        profile: &FunctionProfile,
        now_us: u64,
    ) -> Option<(usize, ContainerId)> {
        self.inner.admit_migrated(profile, now_us)
    }

    fn evict_all(&mut self) -> Vec<crate::trace::FunctionId> {
        self.inner.evict_all()
    }

    // An adaptive node manages its own split; the cluster controller must
    // not fight its hill-climbing loop, so external resizes are refused
    // (`small_frac` stays `None` via the trait default).
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_trace_with, InitOccupancy};
    use crate::trace::synth::{synthesize, SynthConfig};
    use crate::trace::{FunctionId, SizeClass};

    fn profile(id: u32, mem: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(id),
            app_id: id,
            mem_mb: mem,
            app_mem_mb: mem,
            cold_start_us: 1_000_000,
            warm_start_us: 1_000,
            exec_us_mean: 10_000,
            class: if mem >= 200 { SizeClass::Large } else { SizeClass::Small },
            slo_ms: None,
        }
    }

    #[test]
    fn starts_at_initial_split() {
        let b = AdaptiveBalancer::new(
            10_240,
            AdaptiveConfig::default(),
            PolicyKind::Lru,
            PolicyKind::Lru,
        );
        assert_eq!(b.small_frac, 0.8);
        assert_eq!(b.inner().pool(0).capacity_mb(), 8_192);
    }

    #[test]
    fn shifts_capacity_toward_starving_large_pool() {
        // 1 GB node, 90-10: the 102 MB large pool drops every 350 MB
        // function -> pressure should shift capacity to the large pool.
        let cfg = AdaptiveConfig {
            initial_frac: 0.9,
            interval_us: 1_000,
            step: 0.1,
            min_frac: 0.5,
            ..AdaptiveConfig::default()
        };
        let mut b = AdaptiveBalancer::new(1024, cfg, PolicyKind::Lru, PolicyKind::Lru);
        let large = profile(0, 350);
        let mut t = 0;
        for _ in 0..100 {
            t += 500;
            // Release immediately on admission so the node stays quiescent
            // (rebalances are deferred while containers are in flight).
            match b.dispatch(&large, t) {
                Outcome::Hit { pool, container } | Outcome::Cold { pool, container } => {
                    b.release(pool, container, t + 10);
                }
                Outcome::Drop => {}
            }
        }
        assert!(b.rebalances > 0, "should have rebalanced");
        assert!(b.small_frac < 0.9, "capacity must shift to large pool");
        // Eventually the large pool can admit the function.
        let outcome = b.dispatch(&large, t + 1_000_000);
        assert!(!outcome.is_drop(), "large fn fits after rebalance: {outcome:?}");
    }

    #[test]
    fn no_rebalance_without_pressure() {
        let cfg = AdaptiveConfig { interval_us: 1_000, ..AdaptiveConfig::default() };
        let mut b = AdaptiveBalancer::new(8 * 1024, cfg, PolicyKind::Lru, PolicyKind::Lru);
        let small = profile(0, 40);
        let mut t = 0;
        let mut pending = Vec::new();
        for _ in 0..200 {
            t += 500;
            match b.dispatch(&small, t) {
                Outcome::Hit { pool, container } | Outcome::Cold { pool, container } => {
                    pending.push((pool, container));
                }
                Outcome::Drop => {}
            }
            if let Some((p, c)) = pending.pop() {
                b.release(p, c, t + 100);
            }
        }
        assert_eq!(b.rebalances, 0);
        assert_eq!(b.small_frac, 0.8);
    }

    #[test]
    fn adaptive_helps_at_very_low_memory() {
        // The §7.3 hypothesis: at 2 GB the static 80-20 split wastes
        // capacity; adaptive should not be (much) worse, and usually
        // reduces drops. Assert it is within noise or better.
        let synth = SynthConfig {
            seed: 31,
            n_small: 60,
            n_large: 8,
            duration_us: 900_000_000,
            rate_per_sec: 25.0,
            ..crate::experiments::paper_workload()
        };
        let trace = synthesize(&synth);
        let mut stat = Balancer::kiss(2 * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
        let rs = run_trace_with(&trace, &mut stat, InitOccupancy::HoldsMemory);
        let mut adap = AdaptiveBalancer::new(
            2 * 1024,
            AdaptiveConfig::default(),
            PolicyKind::Lru,
            PolicyKind::Lru,
        );
        let ra = run_trace_with(&trace, &mut adap, InitOccupancy::HoldsMemory);
        assert!(ra.is_consistent());
        assert!(
            ra.overall.drop_pct() <= rs.overall.drop_pct() + 3.0,
            "adaptive {:.2}% vs static {:.2}% (rebalances {})",
            ra.overall.drop_pct(),
            rs.overall.drop_pct(),
            adap.rebalances
        );
    }

    #[test]
    fn clamps_respect_bounds() {
        let cfg = AdaptiveConfig {
            initial_frac: 0.55,
            interval_us: 100,
            step: 0.2,
            min_frac: 0.5,
            max_frac: 0.9,
            ..AdaptiveConfig::default()
        };
        let mut b = AdaptiveBalancer::new(1024, cfg, PolicyKind::Lru, PolicyKind::Lru);
        let large = profile(0, 350);
        let mut t = 0;
        for _ in 0..200 {
            t += 200;
            let _ = b.dispatch(&large, t);
        }
        assert!(b.small_frac >= 0.5 - 1e-9, "{}", b.small_frac);
    }
}
