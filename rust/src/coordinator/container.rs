//! Container model: one provisioned function instance in a warm pool.

use crate::trace::FunctionId;

/// Pool-global container identifier (never reused within a pool's lifetime
/// — monotonically allocated, so stale handles are detectable).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContainerId(pub u64);

/// Lifecycle state of a provisioned container.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ContainerState {
    /// Warm and idle: reusable by the next invocation of its function,
    /// evictable by the replacement policy.
    Idle,
    /// Executing an invocation until the recorded completion time; holds
    /// memory and is NOT evictable (drops happen when too much of the pool
    /// is busy — the paper's extended drop metric).
    Busy,
}

/// One container instance.
#[derive(Clone, Debug)]
pub struct Container {
    /// Pool-global identifier (see [`ContainerId`]).
    pub id: ContainerId,
    /// The function this container is provisioned for.
    pub func: FunctionId,
    /// Memory footprint (MB) while resident.
    pub mem_mb: u32,
    /// Current lifecycle state (idle = warm and evictable).
    pub state: ContainerState,
    /// Last time (µs) this container started serving an invocation.
    pub last_used_us: u64,
    /// Number of invocations served by this container.
    pub uses: u64,
    /// Cold-start cost of the function (µs) — the GreedyDual policy's
    /// "cost" term, cached here to keep evictions O(log n).
    pub cold_cost_us: u64,
    /// GreedyDual priority at last touch (see policy::greedy_dual).
    pub gd_priority: f64,
}

impl Container {
    /// A freshly admitted container, born busy serving its first
    /// invocation at `now_us`.
    pub fn new(
        id: ContainerId,
        func: FunctionId,
        mem_mb: u32,
        cold_cost_us: u64,
        now_us: u64,
    ) -> Self {
        Self {
            id,
            func,
            mem_mb,
            state: ContainerState::Busy, // born serving its first invocation
            last_used_us: now_us,
            uses: 1,
            cold_cost_us,
            gd_priority: 0.0,
        }
    }

    /// Whether the container is warm and idle (reusable / evictable).
    pub fn is_idle(&self) -> bool {
        self.state == ContainerState::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_container_is_busy_with_one_use() {
        let c = Container::new(ContainerId(1), FunctionId(3), 40, 1_000_000, 17);
        assert_eq!(c.state, ContainerState::Busy);
        assert_eq!(c.uses, 1);
        assert_eq!(c.last_used_us, 17);
        assert!(!c.is_idle());
    }
}
