//! The load balancer: KiSS's size-aware partitioning logic, plus the
//! unified-pool baseline — both behind [`Dispatcher`], so experiments
//! isolate exactly the policy difference (paper §4.5).
//!
//! KiSS (paper §3.2): node memory is split into independent warm pools
//! (default 80% small / 20% large, threshold between the small and large
//! container size modes); the request handler consults the workload
//! analyzer, and the balancer routes each function to its partition's
//! pool. Each pool runs its own replacement policy ("Policy
//! Independence", §6.4). The implementation generalizes to N partitions
//! ("the ability to add more pools as workload patterns evolve", §3.3).

use super::analyzer::WorkloadAnalyzer;
use super::container::ContainerId;
use super::policy::PolicyKind;
use super::pool::{Acquire, WarmPool};
use super::{Dispatcher, Outcome};
use crate::trace::FunctionProfile;

/// One memory partition: functions with `mem_mb < max_mb` (and not claimed
/// by an earlier partition) route here.
#[derive(Clone, Debug)]
pub struct PartitionSpec {
    /// Human-readable partition name (`small`/`large`/`unified`/…).
    pub name: &'static str,
    /// Fraction of node memory given to this partition (Σ ≈ 1.0).
    pub frac: f64,
    /// Exclusive upper size bound routed to this partition; the last
    /// partition must use `u32::MAX` to be a catch-all.
    pub max_mb: u32,
    /// Replacement policy of this partition's pool.
    pub policy: PolicyKind,
}

/// KiSS / baseline coordinator over one edge node.
pub struct Balancer {
    specs: Vec<PartitionSpec>,
    pools: Vec<WarmPool>,
    /// The online workload analyzer fed by every dispatch (Figure 6's
    /// "workload analyser" box).
    pub analyzer: WorkloadAnalyzer,
    total_mb: u64,
}

impl Balancer {
    /// Build from explicit partitions. Panics on an invalid spec (fractions
    /// not ≈1, unsorted bounds, or a non-catch-all final partition).
    pub fn new(total_mb: u64, specs: Vec<PartitionSpec>) -> Self {
        assert!(!specs.is_empty());
        let frac_sum: f64 = specs.iter().map(|s| s.frac).sum();
        assert!(
            (frac_sum - 1.0).abs() < 1e-6,
            "partition fractions must sum to 1, got {frac_sum}"
        );
        assert!(
            specs.windows(2).all(|w| w[0].max_mb < w[1].max_mb),
            "partition bounds must be strictly increasing"
        );
        assert_eq!(
            specs.last().unwrap().max_mb,
            u32::MAX,
            "last partition must be a catch-all"
        );
        let pools = specs
            .iter()
            .map(|s| WarmPool::new((total_mb as f64 * s.frac).round() as u64, s.policy.build()))
            .collect();
        Self { specs, pools, analyzer: WorkloadAnalyzer::default(), total_mb }
    }

    /// The paper's baseline: one unified pool, LRU by default.
    pub fn baseline(total_mb: u64, policy: PolicyKind) -> Self {
        Self::new(
            total_mb,
            vec![PartitionSpec { name: "unified", frac: 1.0, max_mb: u32::MAX, policy }],
        )
    }

    /// KiSS with a small/large split. `small_frac` is the small pool's
    /// share (the paper's "80-20" = 0.8); `threshold_mb` separates the
    /// classes (paper: between the 30–60 MB and 300–400 MB modes; the
    /// cloud analysis found ~225 MB).
    pub fn kiss(
        total_mb: u64,
        small_frac: f64,
        threshold_mb: u32,
        small_policy: PolicyKind,
        large_policy: PolicyKind,
    ) -> Self {
        assert!((0.0..1.0).contains(&small_frac) && small_frac > 0.0);
        Self::new(
            total_mb,
            vec![
                PartitionSpec {
                    name: "small",
                    frac: small_frac,
                    max_mb: threshold_mb,
                    policy: small_policy,
                },
                PartitionSpec {
                    name: "large",
                    frac: 1.0 - small_frac,
                    max_mb: u32::MAX,
                    policy: large_policy,
                },
            ],
        )
    }

    /// Borrow one partition's pool by index.
    pub fn pool(&self, idx: usize) -> &WarmPool {
        &self.pools[idx]
    }

    /// All partition pools, in spec order.
    pub fn pools(&self) -> &[WarmPool] {
        &self.pools
    }

    /// Total node memory (MB) across partitions.
    pub fn total_mb(&self) -> u64 {
        self.total_mb
    }

    /// Number of partitions (1 = baseline, 2 = KiSS, N = generalized).
    pub fn partition_count(&self) -> usize {
        self.pools.len()
    }

    /// Total evictions across pools (bench metric).
    pub fn evictions(&self) -> u64 {
        self.pools.iter().map(|p| p.evictions).sum()
    }

    /// Extension: reap idle containers last used before `cutoff_us` in
    /// every pool (fixed keep-alive TTL). Returns the number reaped.
    pub fn expire_idle_before(&mut self, cutoff_us: u64) -> usize {
        self.pools.iter_mut().map(|p| p.expire_idle_before(cutoff_us)).sum()
    }

    /// Live-resize a two-pool (KiSS) split to `small_frac`, preserving all
    /// warm state that still fits (adaptive partitioning, paper §7.3).
    /// Shrinking a pool evicts per policy; growing is free.
    pub fn set_split(&mut self, small_frac: f64) {
        assert_eq!(self.pools.len(), 2, "set_split requires a two-pool KiSS balancer");
        assert!(small_frac > 0.0 && small_frac < 1.0);
        let small_cap = (self.total_mb as f64 * small_frac).round() as u64;
        let large_cap = self.total_mb - small_cap;
        self.specs[0].frac = small_frac;
        self.specs[1].frac = 1.0 - small_frac;
        self.pools[0].set_capacity_mb(small_cap);
        self.pools[1].set_capacity_mb(large_cap);
    }

    /// Pool-level invariants (property suite).
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, p) in self.pools.iter().enumerate() {
            p.check_invariants().map_err(|e| format!("pool {i}: {e}"))?;
        }
        Ok(())
    }
}

impl Dispatcher for Balancer {
    fn dispatch(&mut self, profile: &FunctionProfile, now_us: u64) -> Outcome {
        self.analyzer.observe(profile, now_us);
        let pool_idx = self.route(profile);
        match self.pools[pool_idx].try_acquire(profile, now_us) {
            Acquire::Hit(c) => Outcome::Hit { pool: pool_idx, container: c },
            Acquire::Cold(c) => Outcome::Cold { pool: pool_idx, container: c },
            Acquire::Drop => Outcome::Drop,
        }
    }

    fn release(&mut self, pool: usize, container: ContainerId, now_us: u64) {
        self.pools[pool].release(container, now_us);
    }

    fn occupancy(&self) -> Vec<(u64, u64)> {
        self.pools.iter().map(|p| (p.used_mb(), p.capacity_mb())).collect()
    }

    fn used_mb(&self) -> u64 {
        // Hot path: no allocation — sums pool occupancy directly.
        self.pools.iter().map(|p| p.used_mb()).sum()
    }

    fn describe(&self) -> String {
        let parts: Vec<String> = self
            .specs
            .iter()
            .map(|s| {
                format!(
                    "{}<{}MB:{:.0}%:{}",
                    s.name,
                    if s.max_mb == u32::MAX { "inf".into() } else { s.max_mb.to_string() },
                    s.frac * 100.0,
                    s.policy.label()
                )
            })
            .collect();
        parts.join(" | ")
    }

    fn route(&self, profile: &FunctionProfile) -> usize {
        self.specs
            .iter()
            .position(|s| profile.mem_mb < s.max_mb)
            .expect("catch-all partition guarantees a route")
    }

    fn has_idle(&self, profile: &FunctionProfile) -> bool {
        self.pools[self.route(profile)].has_idle(profile.id)
    }

    fn take_idle(&mut self, profile: &FunctionProfile) -> bool {
        let pool = self.route(profile);
        self.pools[pool].take_idle_mru(profile.id).is_some()
    }

    fn can_admit(&self, profile: &FunctionProfile) -> bool {
        self.pools[self.route(profile)].can_admit(profile.mem_mb)
    }

    fn admit_migrated(
        &mut self,
        profile: &FunctionProfile,
        now_us: u64,
    ) -> Option<(usize, ContainerId)> {
        let pool = self.route(profile);
        self.pools[pool].admit_warm(profile, now_us).map(|c| (pool, c))
    }

    fn small_frac(&self) -> Option<f64> {
        (self.pools.len() == 2).then_some(self.specs[0].frac)
    }

    fn try_set_split(&mut self, small_frac: f64) -> bool {
        if self.pools.len() != 2 || small_frac <= 0.0 || small_frac >= 1.0 {
            return false;
        }
        self.set_split(small_frac);
        true
    }

    fn evict_all(&mut self) -> Vec<crate::trace::FunctionId> {
        self.pools.iter_mut().flat_map(|p| p.drain_all()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{FunctionId, SizeClass};

    fn profile(id: u32, mem: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(id),
            app_id: 0,
            mem_mb: mem,
            app_mem_mb: mem,
            cold_start_us: 1_000_000,
            warm_start_us: 1_000,
            exec_us_mean: 10_000,
            class: if mem >= 200 { SizeClass::Large } else { SizeClass::Small },
            slo_ms: None,
        }
    }

    #[test]
    fn kiss_routes_by_size_threshold() {
        let b = Balancer::kiss(1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
        assert_eq!(b.route(&profile(0, 40)), 0);
        assert_eq!(b.route(&profile(1, 199)), 0);
        assert_eq!(b.route(&profile(2, 200)), 1);
        assert_eq!(b.route(&profile(3, 400)), 1);
    }

    #[test]
    fn kiss_splits_capacity_80_20() {
        let b = Balancer::kiss(10_240, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
        assert_eq!(b.pool(0).capacity_mb(), 8_192);
        assert_eq!(b.pool(1).capacity_mb(), 2_048);
    }

    #[test]
    fn baseline_is_single_catch_all() {
        let b = Balancer::baseline(4096, PolicyKind::Lru);
        assert_eq!(b.partition_count(), 1);
        assert_eq!(b.route(&profile(0, 40)), 0);
        assert_eq!(b.route(&profile(1, 4000)), 0);
        assert_eq!(b.pool(0).capacity_mb(), 4096);
    }

    #[test]
    fn kiss_isolates_partitions() {
        // Large container cannot displace small-pool contents: fill the
        // small pool, then admit a large function — small pool untouched.
        let mut b = Balancer::kiss(1000, 0.5, 200, PolicyKind::Lru, PolicyKind::Lru);
        let s = profile(0, 100);
        let Outcome::Cold { pool: 0, container: c } = b.dispatch(&s, 0) else {
            panic!()
        };
        b.release(0, c, 1);
        let l = profile(1, 400);
        let Outcome::Cold { pool: 1, .. } = b.dispatch(&l, 2) else { panic!() };
        // Small pool still holds its idle container.
        assert_eq!(b.pool(0).idle_count(), 1);
        b.check_invariants().unwrap();
    }

    #[test]
    fn baseline_allows_cross_class_displacement() {
        // The Figure-1 pathology: in a unified pool the large container
        // evicts the small one.
        let mut b = Balancer::baseline(500, PolicyKind::Lru);
        let s = profile(0, 100);
        let Outcome::Cold { pool, container } = b.dispatch(&s, 0) else { panic!() };
        b.release(pool, container, 1);
        let l = profile(1, 450);
        let Outcome::Cold { .. } = b.dispatch(&l, 2) else { panic!() };
        assert_eq!(b.pool(0).idle_count(), 0, "small container was displaced");
        assert_eq!(b.evictions(), 1);
    }

    #[test]
    fn kiss_large_pool_too_small_drops_large_fn() {
        // 90-10 split on a 1 GB node: the large pool has 102 MB — no 300 MB
        // function can ever run. This is the over-prioritization failure
        // mode the paper observes for 90-10 at low memory.
        let mut b = Balancer::kiss(1024, 0.9, 200, PolicyKind::Lru, PolicyKind::Lru);
        assert!(b.dispatch(&profile(0, 300), 0).is_drop());
    }

    #[test]
    fn three_way_partition_supported() {
        let b = Balancer::new(
            3000,
            vec![
                PartitionSpec { name: "s", frac: 0.5, max_mb: 100, policy: PolicyKind::Lru },
                PartitionSpec { name: "m", frac: 0.3, max_mb: 300, policy: PolicyKind::Freq },
                PartitionSpec {
                    name: "l",
                    frac: 0.2,
                    max_mb: u32::MAX,
                    policy: PolicyKind::GreedyDual,
                },
            ],
        );
        assert_eq!(b.route(&profile(0, 50)), 0);
        assert_eq!(b.route(&profile(1, 150)), 1);
        assert_eq!(b.route(&profile(2, 350)), 2);
    }

    #[test]
    #[should_panic(expected = "fractions must sum to 1")]
    fn bad_fractions_rejected() {
        Balancer::new(
            1000,
            vec![PartitionSpec { name: "x", frac: 0.5, max_mb: u32::MAX, policy: PolicyKind::Lru }],
        );
    }

    #[test]
    #[should_panic(expected = "catch-all")]
    fn missing_catch_all_rejected() {
        Balancer::new(
            1000,
            vec![PartitionSpec { name: "x", frac: 1.0, max_mb: 100, policy: PolicyKind::Lru }],
        );
    }

    #[test]
    fn migration_hooks_route_to_the_right_pool() {
        let mut b = Balancer::kiss(1000, 0.5, 200, PolicyKind::Lru, PolicyKind::Lru);
        let small = profile(0, 100);
        assert!(!b.has_idle(&small));
        let Outcome::Cold { pool: 0, container } = b.dispatch(&small, 0) else { panic!() };
        b.release(0, container, 1);
        assert!(b.has_idle(&small));
        // Donate the idle container: the small pool empties.
        assert!(b.take_idle(&small));
        assert!(!b.has_idle(&small));
        assert_eq!(b.pool(0).used_mb(), 0);
        // Admission routes by size: a large profile admits into pool 1.
        let large = profile(1, 400);
        assert!(b.can_admit(&large));
        let (pool, c) = b.admit_migrated(&large, 2).unwrap();
        assert_eq!(pool, 1);
        b.release(pool, c, 3);
        b.check_invariants().unwrap();
    }

    #[test]
    fn split_control_hooks() {
        let mut b = Balancer::kiss(1000, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
        assert_eq!(b.small_frac(), Some(0.8));
        assert!(b.try_set_split(0.6));
        assert_eq!(b.small_frac(), Some(0.6));
        assert_eq!(b.pool(0).capacity_mb(), 600);
        assert!(!b.try_set_split(0.0), "degenerate splits refused");
        // Baseline (one pool) has no adjustable split.
        let mut base = Balancer::baseline(1000, PolicyKind::Lru);
        assert_eq!(base.small_frac(), None);
        assert!(!base.try_set_split(0.5));
    }

    #[test]
    fn describe_mentions_partitions() {
        let b = Balancer::kiss(1024, 0.8, 225, PolicyKind::Lru, PolicyKind::GreedyDual);
        let d = b.describe();
        assert!(d.contains("small"), "{d}");
        assert!(d.contains("large"), "{d}");
        assert!(d.contains("80%"), "{d}");
        assert!(d.contains("gd"), "{d}");
    }
}
