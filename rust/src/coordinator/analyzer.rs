//! Online workload analyzer — the "workload analyser" box in the paper's
//! Figure 6. Maintains O(1) per-function profiles (EWMA invocation rate,
//! footprint, observed durations) that the load balancer and the
//! GreedyDual policy can consult, and can *suggest* a size threshold from
//! the footprint distribution it has seen (the paper's offline analysis
//! found the 225 MB valley; this is its online counterpart, used by the
//! adaptive-threshold ablation).

use crate::trace::{FunctionId, FunctionProfile};
use crate::util::stats::{Ewma, Histogram};

/// Per-function online profile.
#[derive(Clone, Debug)]
pub struct FuncStats {
    /// EWMA of the inter-arrival time (µs) — inverse of invocation rate.
    pub iat_us: Ewma,
    /// Time (µs) of the most recent arrival, once one was seen.
    pub last_arrival_us: Option<u64>,
    /// Total arrivals observed for this function.
    pub invocations: u64,
    /// Memory footprint (MB) from the function's profile.
    pub mem_mb: u32,
}

/// Online profiler. All updates are O(1); `suggest_threshold_mb` is O(bins).
pub struct WorkloadAnalyzer {
    /// Dense per-function profiles, indexed by FunctionId (ids are dense
    /// by construction). Vec indexing beats hashing on the per-event hot
    /// path — see EXPERIMENTS.md §Perf.
    funcs: Vec<Option<FuncStats>>,
    seen: usize,
    /// Footprint histogram over observed functions (MB), for threshold
    /// suggestion. 0–1024 MB in 8 MB bins.
    footprint: Histogram,
    alpha: f64,
}

impl Default for WorkloadAnalyzer {
    fn default() -> Self {
        Self::new(0.2)
    }
}

impl WorkloadAnalyzer {
    /// An empty analyzer whose EWMAs decay with smoothing factor
    /// `alpha`.
    pub fn new(alpha: f64) -> Self {
        Self {
            funcs: Vec::new(),
            seen: 0,
            footprint: Histogram::new(0.0, 1024.0, 128),
            alpha,
        }
    }

    /// Record an arrival (called by the request handler for every
    /// invocation, before routing).
    pub fn observe(&mut self, profile: &FunctionProfile, now_us: u64) {
        let idx = profile.id.0 as usize;
        if idx >= self.funcs.len() {
            self.funcs.resize_with(idx + 1, || None);
        }
        let entry = self.funcs[idx].get_or_insert_with(|| {
            // First sighting: account the footprint once per function.
            self.seen += 1;
            FuncStats {
                iat_us: Ewma::new(self.alpha),
                last_arrival_us: None,
                invocations: 0,
                mem_mb: profile.mem_mb,
            }
        });
        if entry.invocations == 0 {
            self.footprint.push(profile.mem_mb as f64);
        }
        entry.invocations += 1;
        if let Some(prev) = entry.last_arrival_us {
            entry.iat_us.push((now_us - prev) as f64);
        }
        entry.last_arrival_us = Some(now_us);
    }

    /// The online profile of `f`, if it has been observed.
    pub fn stats(&self, f: FunctionId) -> Option<&FuncStats> {
        self.funcs.get(f.0 as usize)?.as_ref()
    }

    /// EWMA invocation rate (per second), if two+ arrivals were seen.
    pub fn rate_per_sec(&self, f: FunctionId) -> Option<f64> {
        let iat = self.stats(f)?.iat_us.get()?;
        if iat <= 0.0 {
            return None;
        }
        Some(1e6 / iat)
    }

    /// Number of distinct functions observed so far.
    pub fn functions_seen(&self) -> usize {
        self.seen
    }

    /// Suggest a small/large threshold (MB) as the widest empty valley in
    /// the footprint histogram between the two occupied extremes — the
    /// online analogue of the paper's Fig. 2 "spike at ~225 MB" analysis.
    /// Returns `None` until the distribution is clearly bimodal (an empty
    /// gap of at least `min_gap_bins` bins).
    pub fn suggest_threshold_mb(&self, min_gap_bins: usize) -> Option<u32> {
        let bins = self.footprint.bins();
        let width = 1024.0 / bins.len() as f64;
        let first = bins.iter().position(|&c| c > 0)?;
        let last = bins.iter().rposition(|&c| c > 0)?;
        if first == last {
            return None;
        }
        // Widest run of empty bins strictly inside [first, last].
        let mut best: Option<(usize, usize)> = None; // (len, start)
        let mut run_start = None;
        for i in first..=last {
            if bins[i] == 0 {
                run_start.get_or_insert(i);
            } else if let Some(s) = run_start.take() {
                let len = i - s;
                if best.map(|(l, _)| len > l).unwrap_or(true) {
                    best = Some((len, s));
                }
            }
        }
        let (len, start) = best?;
        if len < min_gap_bins {
            return None;
        }
        // Midpoint of the gap.
        Some(((start as f64 + len as f64 / 2.0) * width) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SizeClass;

    fn profile(id: u32, mem: u32) -> FunctionProfile {
        FunctionProfile {
            id: FunctionId(id),
            app_id: 0,
            mem_mb: mem,
            app_mem_mb: mem,
            cold_start_us: 0,
            warm_start_us: 0,
            exec_us_mean: 0,
            class: SizeClass::Small,
            slo_ms: None,
        }
    }

    #[test]
    fn rate_estimation_from_regular_arrivals() {
        let mut a = WorkloadAnalyzer::default();
        let f = profile(0, 40);
        for i in 0..20 {
            a.observe(&f, i * 100_000); // every 100 ms -> 10/s
        }
        let r = a.rate_per_sec(FunctionId(0)).unwrap();
        assert!((r - 10.0).abs() < 0.5, "rate {r}");
    }

    #[test]
    fn no_rate_before_second_arrival() {
        let mut a = WorkloadAnalyzer::default();
        a.observe(&profile(0, 40), 0);
        assert!(a.rate_per_sec(FunctionId(0)).is_none());
    }

    #[test]
    fn footprint_counted_once_per_function() {
        let mut a = WorkloadAnalyzer::default();
        let f = profile(0, 40);
        for i in 0..5 {
            a.observe(&f, i);
        }
        assert_eq!(a.footprint.count(), 1);
        assert_eq!(a.functions_seen(), 1);
    }

    #[test]
    fn threshold_found_between_bimodal_classes() {
        let mut a = WorkloadAnalyzer::default();
        for i in 0..30 {
            a.observe(&profile(i, 30 + i % 30), 0); // 30-59 MB
        }
        for i in 0..10 {
            a.observe(&profile(100 + i, 300 + (i % 10) * 10), 0); // 300-390 MB
        }
        let th = a.suggest_threshold_mb(3).unwrap();
        assert!(
            (80..=290).contains(&th),
            "threshold {th} should fall in the 60..300 valley"
        );
    }

    #[test]
    fn no_threshold_for_unimodal_distribution() {
        let mut a = WorkloadAnalyzer::default();
        for i in 0..20 {
            a.observe(&profile(i, 40 + i), 0);
        }
        assert_eq!(a.suggest_threshold_mb(3), None);
    }
}
