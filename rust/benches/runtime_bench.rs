//! PJRT runtime benchmarks: payload compile (cold-start) cost and
//! execute latency/throughput per batch variant — the real numbers behind
//! the live-serving example. Skips if artifacts are missing.

use std::path::{Path, PathBuf};
use std::time::Duration;

use kiss_faas::bench::{group, Bencher};
use kiss_faas::runtime::{load_manifest, read_f32_bin, Engine};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        println!("SKIP runtime_bench: no artifacts (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    let specs = load_manifest(&artifacts_dir()).unwrap();

    group("payload compile (container cold-start cost on this host)");
    for spec in &specs {
        let r = Bencher::new(&format!("runtime/compile/{}", spec.name))
            .warmup(Duration::from_millis(1))
            .target(Duration::from_secs(1))
            .max_iters(20)
            .run(|| {
                std::hint::black_box(engine.compile_fresh(spec).unwrap());
            });
        println!("{r}");
    }

    group("payload execute (warm path)");
    for spec in &specs {
        engine.load(spec).unwrap();
        let x = read_f32_bin(&spec.golden_input_file).unwrap();
        let batch = spec.batch() as f64;
        let name = spec.name.clone();
        let payload = engine.get(&name).unwrap();
        let r = Bencher::new(&format!("runtime/execute/{name}"))
            .items_per_iter(batch) // per-sample throughput
            .target(Duration::from_secs(1))
            .run(|| {
                std::hint::black_box(payload.run(&x).unwrap());
            });
        println!("{r}  (samples/s)");
    }

    group("batch amortization (iot_mlp b1 vs b8, per-sample)");
    {
        let b1 = engine.get("iot_mlp_b1").unwrap();
        let x1 = read_f32_bin(&b1.spec.golden_input_file).unwrap();
        let r1 = Bencher::new("runtime/per-sample/b1")
            .items_per_iter(1.0)
            .target(Duration::from_secs(1))
            .run(|| {
                std::hint::black_box(b1.run(&x1).unwrap());
            });
        println!("{r1}");
        let b8 = engine.get("iot_mlp_b8").unwrap();
        let x8 = read_f32_bin(&b8.spec.golden_input_file).unwrap();
        let r8 = Bencher::new("runtime/per-sample/b8")
            .items_per_iter(8.0)
            .target(Duration::from_secs(1))
            .run(|| {
                std::hint::black_box(b8.run(&x8).unwrap());
            });
        println!("{r8}");
        println!(
            "  batching speedup (per-sample): {:.2}x",
            r8.item_rate() / r1.item_rate()
        );
    }
}
