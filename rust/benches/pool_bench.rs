//! Warm-pool micro-benchmarks: the per-invocation hot path (route +
//! acquire + release) and eviction throughput, per policy. DESIGN.md §6
//! target: route+pool decision < 1 µs p50, no allocation in steady state.

use kiss_faas::bench::{group, Bencher};
use kiss_faas::coordinator::policy::PolicyKind;
use kiss_faas::coordinator::pool::{Acquire, WarmPool};
use kiss_faas::coordinator::{Balancer, Dispatcher};
use kiss_faas::trace::{FunctionId, FunctionProfile, SizeClass};

fn profile(id: u32, mem: u32) -> FunctionProfile {
    FunctionProfile {
        id: FunctionId(id),
        app_id: id,
        mem_mb: mem,
        app_mem_mb: mem,
        cold_start_us: 1_000_000,
        warm_start_us: 1_000,
        exec_us_mean: 100_000,
        class: if mem >= 200 { SizeClass::Large } else { SizeClass::Small },
        slo_ms: None,
    }
}

fn main() {
    group("pool: steady-state hit path (acquire+release)");
    for kind in PolicyKind::ALL {
        let mut pool = WarmPool::new(64 * 1024, kind.build());
        let p = profile(0, 40);
        // Pre-warm one container.
        let Acquire::Cold(id) = pool.try_acquire(&p, 0) else { unreachable!() };
        pool.release(id, 1);
        let mut t = 2u64;
        let r = Bencher::new(&format!("pool/hit-path/{}", kind.label())).run(|| {
            t += 10;
            let Acquire::Hit(id) = pool.try_acquire(&p, t) else { unreachable!() };
            pool.release(id, t + 5);
        });
        println!("{r}");
        assert!(r.p50_ns < 1_000.0, "hit path p50 {} ns exceeds 1 µs target", r.p50_ns);
    }

    group("pool: cold admission with eviction (churn)");
    for kind in PolicyKind::ALL {
        // Pool fits 100 idle containers; every admission evicts one.
        let mut pool = WarmPool::new(100 * 40, kind.build());
        let profiles: Vec<FunctionProfile> = (0..1000).map(|i| profile(i, 40)).collect();
        let mut t = 0u64;
        // Fill.
        for p in profiles.iter().take(100) {
            t += 1;
            if let Acquire::Cold(id) = pool.try_acquire(p, t) {
                pool.release(id, t);
            }
        }
        let mut i = 100usize;
        let r = Bencher::new(&format!("pool/evict-churn/{}", kind.label())).run(|| {
            t += 1;
            i = (i + 1) % 1000;
            if let Acquire::Cold(id) = pool.try_acquire(&profiles[i], t) {
                pool.release(id, t);
            }
        });
        println!("{r}");
    }

    group("balancer: full dispatch decision (route + analyzer + pool)");
    let mut b = Balancer::kiss(8 * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
    let profiles: Vec<FunctionProfile> =
        (0..64).map(|i| profile(i, if i % 6 == 5 { 350 } else { 40 })).collect();
    let mut t = 0u64;
    let mut pending: Vec<(usize, kiss_faas::coordinator::ContainerId)> = Vec::new();
    let mut i = 0usize;
    let r = Bencher::new("balancer/dispatch/64fns").run(|| {
        t += 50;
        i = (i + 1) % 64;
        match b.dispatch(&profiles[i], t) {
            kiss_faas::coordinator::Outcome::Hit { pool, container }
            | kiss_faas::coordinator::Outcome::Cold { pool, container } => {
                pending.push((pool, container));
            }
            kiss_faas::coordinator::Outcome::Drop => {}
        }
        if pending.len() > 32 {
            let (pool, c) = pending.remove(0);
            b.release(pool, c, t);
        }
    });
    println!("{r}");

    group("pool: scaling with container count (LRU victim selection)");
    for n in [100usize, 1_000, 10_000] {
        let mut pool = WarmPool::new((n as u64 + 10) * 40, PolicyKind::Lru.build());
        let profiles: Vec<FunctionProfile> = (0..n as u32 + 10).map(|i| profile(i, 40)).collect();
        let mut t = 0u64;
        for p in profiles.iter().take(n) {
            t += 1;
            if let Acquire::Cold(id) = pool.try_acquire(p, t) {
                pool.release(id, t);
            }
        }
        let mut i = n;
        let r = Bencher::new(&format!("pool/admit-evict/{n}-resident")).run(|| {
            t += 1;
            i = (i + 1) % profiles.len();
            if let Acquire::Cold(id) = pool.try_acquire(&profiles[i], t) {
                pool.release(id, t);
            }
        });
        println!("{r}");
    }
}
