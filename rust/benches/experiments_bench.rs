//! One bench per paper table/figure family: times the regeneration of
//! each experiment (reduced workload so the whole suite stays minutes,
//! same code paths as `repro experiment ...`), plus the ablations
//! DESIGN.md calls out (threshold sensitivity, init-occupancy model,
//! adaptive vs static threshold).

use kiss_faas::bench::{group, Bencher};
use kiss_faas::coordinator::policy::PolicyKind;
use kiss_faas::coordinator::Balancer;
use kiss_faas::experiments::{
    fairness, paper_workload, policy_independence, stress, sweeps, workload, Artifact, ExpParams,
};
use kiss_faas::sim::{run_trace_with, InitOccupancy};
use kiss_faas::trace::synth::{synthesize, SynthConfig};
use kiss_faas::trace::SizeClass;
use std::time::Duration;

fn bench_workload() -> SynthConfig {
    SynthConfig {
        seed: 7,
        n_small: 60,
        n_large: 8,
        duration_us: 600_000_000, // 10 min
        rate_per_sec: 25.0,
        ..paper_workload()
    }
}

fn main() {
    let w = bench_workload();
    let one = |name: &str, f: &dyn Fn() -> String| {
        let r = Bencher::new(name)
            .warmup(Duration::from_millis(1))
            .target(Duration::from_millis(1))
            .max_iters(1)
            .run(|| {
                std::hint::black_box(f());
            });
        println!("{r}");
    };

    group("figures: workload analysis (figs 2-5)");
    one("exp/fig2", &|| workload::fig2(&w).render_text());
    one("exp/fig3", &|| workload::fig3(&w).render_text());
    one("exp/fig4", &|| workload::fig4(&w).render_text());
    one("exp/fig5", &|| workload::fig5(&w).render_text());

    group("figures: cold-start / drop sweeps (figs 7-9)");
    one("exp/fig7 (6 configs x 11 mem points)", &|| sweeps::fig7(&w).render());
    one("exp/fig8", &|| sweeps::fig8(&w).render());
    one("exp/fig9", &|| sweeps::fig9(&w).render());

    group("figures: fairness (figs 10-13)");
    one("exp/fig10", &|| fairness::fig10(&w).render());
    one("exp/fig11", &|| fairness::fig11(&w).render());
    one("exp/fig12", &|| fairness::fig12(&w).render());
    one("exp/fig13", &|| fairness::fig13(&w).render());

    group("figures: policy independence (figs 14-16)");
    one("exp/fig14", &|| policy_independence::fig14(&w).render());
    one("exp/fig15", &|| policy_independence::fig15(&w).render());
    one("exp/fig16", &|| policy_independence::fig16(&w).render());

    group("stress test (§6.5, 2% scale)");
    one("exp/stress", &|| {
        let (k, b) = stress::stress(10, 0.02, 2025);
        stress::render(&k, &b)
    });

    group("artifact rendering (fig8 sweep -> text/json/csv)");
    {
        let artifact = Artifact::Sweep(sweeps::fig8(&w));
        let entry = kiss_faas::experiments::find("fig8").unwrap();
        let params = ExpParams::default();
        one("artifact/render_text", &|| artifact.render_text());
        one("artifact/render_json", &|| {
            entry.artifact_json(&params, &artifact).to_string_pretty()
        });
        one("artifact/render_csv", &|| artifact.render_csv());
    }

    // ----------------------------------------------------------------- //
    group("ablation: size threshold sensitivity (KiSS 80-20, 4GB)");
    let trace = synthesize(&w);
    for threshold in [100u32, 150, 200, 250, 299] {
        let mut b =
            Balancer::kiss(4 * 1024, 0.8, threshold, PolicyKind::Lru, PolicyKind::Lru);
        let r = run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory);
        println!(
            "  threshold {threshold:>3} MB -> cold {:>6.2}%  drops {:>6.2}%",
            r.overall.cold_start_pct(),
            r.overall.drop_pct()
        );
    }

    group("ablation: init-occupancy model (baseline, 4GB)");
    for (label, occ) in [
        ("latency-only", InitOccupancy::LatencyOnly),
        ("holds-memory", InitOccupancy::HoldsMemory),
    ] {
        let mut b = Balancer::baseline(4 * 1024, PolicyKind::Lru);
        let r = run_trace_with(&trace, &mut b, occ);
        println!(
            "  {label:>13} -> cold {:>6.2}%  drops {:>6.2}%",
            r.overall.cold_start_pct(),
            r.overall.drop_pct()
        );
    }

    group("ablation: adaptive (analyzer-suggested) vs static threshold, 4GB");
    {
        // Learn the threshold online from the first 10% of the trace.
        let mut probe = Balancer::kiss(4 * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
        let tenth = trace.events.len() / 10;
        let probe_trace = kiss_faas::trace::Trace {
            functions: trace.functions.clone(),
            events: trace.events[..tenth].to_vec(),
        };
        run_trace_with(&probe_trace, &mut probe, InitOccupancy::HoldsMemory);
        let suggested = probe.analyzer.suggest_threshold_mb(3).unwrap_or(200);
        for (label, th) in [("static-200", 200u32), ("adaptive", suggested)] {
            let mut b = Balancer::kiss(4 * 1024, 0.8, th, PolicyKind::Lru, PolicyKind::Lru);
            let r = run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory);
            println!(
                "  {label:>10} ({th:>3} MB) -> cold {:>6.2}%  drops {:>6.2}%",
                r.overall.cold_start_pct(),
                r.overall.drop_pct()
            );
        }
    }

    group("ablation: adaptive partitioning (§7.3 future work) vs static at 2-3GB");
    for gb in [2u64, 3] {
        let mut stat = Balancer::kiss(gb * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
        let rs = run_trace_with(&trace, &mut stat, InitOccupancy::HoldsMemory);
        let mut adap = kiss_faas::coordinator::AdaptiveBalancer::new(
            gb * 1024,
            kiss_faas::coordinator::AdaptiveConfig::default(),
            PolicyKind::Lru,
            PolicyKind::Lru,
        );
        let ra = run_trace_with(&trace, &mut adap, InitOccupancy::HoldsMemory);
        println!(
            "  {gb}GB static-80-20 -> cold {:>6.2}%  drops {:>6.2}%",
            rs.overall.cold_start_pct(),
            rs.overall.drop_pct()
        );
        println!(
            "  {gb}GB adaptive     -> cold {:>6.2}%  drops {:>6.2}%  ({} rebalances, final {:.0}-{:.0})",
            ra.overall.cold_start_pct(),
            ra.overall.drop_pct(),
            adap.rebalances,
            adap.small_frac * 100.0,
            (1.0 - adap.small_frac) * 100.0
        );
    }

    group("ablation: function chaining (§1.1) — chained vs plain, 4GB");
    {
        let chained_cfg = SynthConfig {
            chains: Some(kiss_faas::trace::synth::ChainConfig::default()),
            ..bench_workload()
        };
        let chained = synthesize(&chained_cfg);
        for (label, trace) in [("plain", &trace), ("chained", &chained)] {
            let mut kiss = Balancer::kiss(4 * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
            let rk = run_trace_with(trace, &mut kiss, InitOccupancy::HoldsMemory);
            let mut base = Balancer::baseline(4 * 1024, PolicyKind::Lru);
            let rb = run_trace_with(trace, &mut base, InitOccupancy::HoldsMemory);
            println!(
                "  {label:>8} ({} events) -> kiss cold {:>6.2}% vs baseline {:>6.2}% (gap {:+.1} pts)",
                trace.events.len(),
                rk.overall.cold_start_pct(),
                rb.overall.cold_start_pct(),
                rb.overall.cold_start_pct() - rk.overall.cold_start_pct(),
            );
        }
    }

    group("ablation: per-class split sensitivity at 4GB (fig7 cross-section)");
    for split in [0.5, 0.6, 0.7, 0.8, 0.9] {
        let mut b = Balancer::kiss(4 * 1024, split, 200, PolicyKind::Lru, PolicyKind::Lru);
        let r = run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory);
        println!(
            "  split {:>2.0}-{:<2.0} -> cold small {:>6.2}% large {:>6.2}% | drops small {:>6.2}% large {:>6.2}%",
            split * 100.0,
            (1.0 - split) * 100.0,
            r.class(SizeClass::Small).cold_start_pct(),
            r.class(SizeClass::Large).cold_start_pct(),
            r.class(SizeClass::Small).drop_pct(),
            r.class(SizeClass::Large).drop_pct(),
        );
    }
}
