//! Workload-analysis benchmarks: throughput of the Fig 2-5 computations
//! over a ~1M-event trace (they must stay interactive for `repro analyze`)
//! and the trace synthesizer itself.

use kiss_faas::analysis;
use kiss_faas::bench::{group, Bencher};
use kiss_faas::experiments::paper_workload;
use kiss_faas::trace::synth::{synthesize, SynthConfig};
use std::time::Duration;

fn main() {
    group("trace synthesis");
    let big = SynthConfig {
        seed: 23,
        duration_us: 3_600_000_000,
        rate_per_sec: 280.0, // ~1M events
        ..paper_workload()
    };
    let mut trace = None;
    let r = Bencher::new("synth/1M-events/1h")
        .warmup(Duration::from_millis(1))
        .target(Duration::from_secs(2))
        .max_iters(3)
        .run(|| {
            trace = Some(synthesize(&big));
        });
    println!("{r}");
    let trace = trace.unwrap();
    let n = trace.events.len() as f64;
    println!("  trace: {} events", trace.events.len());

    group("analysis over the 1M-event trace");
    let r = Bencher::new("analysis/fig2-footprint")
        .items_per_iter(n)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(analysis::footprint_percentiles(&trace, 225.0));
        });
    println!("{r}");

    let r = Bencher::new("analysis/fig3-trends")
        .items_per_iter(n)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(analysis::invocation_trends(&trace));
        });
    println!("{r}");

    let r = Bencher::new("analysis/fig4-iat-sliding-window")
        .items_per_iter(n)
        .warmup(Duration::from_millis(1))
        .target(Duration::from_secs(2))
        .max_iters(5)
        .run(|| {
            std::hint::black_box(analysis::iat_percentiles(
                &trace,
                3_600_000_000,
                1_800_000_000,
                3.0,
            ));
        });
    println!("{r}");

    let r = Bencher::new("analysis/fig5-coldstart")
        .target(Duration::from_millis(500))
        .run(|| {
            std::hint::black_box(analysis::coldstart_percentiles(&trace));
        });
    println!("{r}");

    group("stress-scale synthesis (§6.5: 4.5M events)");
    let stress = SynthConfig { seed: 1, ..SynthConfig::stress() };
    let r = Bencher::new("synth/stress-4.5M")
        .warmup(Duration::from_millis(1))
        .target(Duration::from_secs(1))
        .max_iters(1)
        .run(|| {
            std::hint::black_box(synthesize(&stress));
        });
    println!("{r}");
}
