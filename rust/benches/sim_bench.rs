//! Simulator hot-path benchmarks: end-to-end event throughput for the
//! baseline and KiSS dispatchers (the number that bounds how fast the
//! full fig7 sweep regenerates), plus the §Perf target check
//! (≥ 10 M simulated invocations/min single-thread — see DESIGN.md §6).

use kiss_faas::bench::{group, Bencher};
use kiss_faas::coordinator::policy::PolicyKind;
use kiss_faas::coordinator::Balancer;
use kiss_faas::experiments::paper_workload;
use kiss_faas::sim::{run_trace_with, InitOccupancy};
use kiss_faas::trace::synth::{synthesize, SynthConfig};

fn main() {
    group("sim: event throughput (15-min edge workload)");
    let synth = SynthConfig {
        seed: 17,
        n_small: 120,
        n_large: 16,
        duration_us: 900_000_000,
        rate_per_sec: 60.0,
        ..paper_workload()
    };
    let trace = synthesize(&synth);
    let n = trace.events.len() as f64;
    println!("trace: {} events, {} functions", trace.events.len(), trace.functions.len());

    let r = Bencher::new("sim/baseline-lru/4GB")
        .items_per_iter(n)
        .run(|| {
            let mut b = Balancer::baseline(4 * 1024, PolicyKind::Lru);
            std::hint::black_box(run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory));
        });
    println!("{r}");
    let events_per_min = r.item_rate() * 60.0;
    println!(
        "  -> {:.1} M simulated invocations/min (target >= 10 M/min): {}",
        events_per_min / 1e6,
        if events_per_min >= 10e6 { "PASS" } else { "MISS" }
    );

    for kind in PolicyKind::ALL {
        let r = Bencher::new(&format!("sim/kiss-80-20-{}/4GB", kind.label()))
            .items_per_iter(n)
            .run(|| {
                let mut b = Balancer::kiss(4 * 1024, 0.8, 200, kind, kind);
                std::hint::black_box(run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory));
            });
        println!("{r}");
    }

    group("sim: init-occupancy ablation (same trace)");
    for (label, occ) in [
        ("latency-only", InitOccupancy::LatencyOnly),
        ("holds-memory", InitOccupancy::HoldsMemory),
    ] {
        let r = Bencher::new(&format!("sim/kiss/8GB/{label}"))
            .items_per_iter(n)
            .run(|| {
                let mut b =
                    Balancer::kiss(8 * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
                std::hint::black_box(run_trace_with(&trace, &mut b, occ));
            });
        println!("{r}");
    }

    group("sim: memory-pressure scaling (events/s vs node size)");
    for gb in [1u64, 4, 16] {
        let r = Bencher::new(&format!("sim/kiss/{gb}GB"))
            .items_per_iter(n)
            .run(|| {
                let mut b =
                    Balancer::kiss(gb * 1024, 0.8, 200, PolicyKind::Lru, PolicyKind::Lru);
                std::hint::black_box(run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory));
            });
        println!("{r}");
    }
}
