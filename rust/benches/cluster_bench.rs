//! Cluster-engine benchmarks: event throughput as the node count scales
//! (the router runs on every arrival, so cluster dispatch must stay in
//! the same class as single-node dispatch), a router comparison at a
//! fixed fleet size, and a multi-trial sweep parallelized across
//! `std::thread` (the embarrassingly-parallel shape the experiment
//! harness uses for seed replication).

// Determinism-contract exemption (see rust/clippy.toml): benchmarks
// measure wall-clock time by definition.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use kiss_faas::bench::{group, Bencher};
use kiss_faas::experiments::paper_workload;
use kiss_faas::sim::cluster::{
    run_cluster, ChurnConfig, ClusterSpec, ControllerConfig, NodePolicy, RouterKind, Topology,
};
use kiss_faas::sim::InitOccupancy;
use kiss_faas::trace::synth::{synthesize, SynthConfig};

const TOTAL_MEM_MB: u64 = 16 * 1024;

fn bench_workload(seed: u64) -> SynthConfig {
    SynthConfig {
        seed,
        n_small: 120,
        n_large: 16,
        duration_us: 900_000_000, // 15 min
        rate_per_sec: 60.0,
        ..paper_workload()
    }
}

fn spec(n: usize, router: RouterKind) -> ClusterSpec {
    ClusterSpec::homogeneous(n, TOTAL_MEM_MB / n as u64, NodePolicy::kiss_default())
        .with_router(router)
        .with_init_occupancy(InitOccupancy::HoldsMemory)
        .with_cloud(80_000)
}

fn main() {
    let trace = synthesize(&bench_workload(17));
    let n_events = trace.events.len() as f64;
    println!("trace: {} events, {} functions", trace.events.len(), trace.functions.len());

    group("cluster: event throughput vs node count (16 GB total, least-loaded)");
    for &n in &[1usize, 2, 4, 8] {
        let s = spec(n, RouterKind::LeastLoaded);
        let r = Bencher::new(&format!("cluster/least-loaded/{n}-nodes"))
            .items_per_iter(n_events)
            .target(Duration::from_secs(1))
            .run(|| {
                std::hint::black_box(run_cluster(&trace, &s));
            });
        println!("{r}");
    }

    group("cluster: router comparison (4 nodes)");
    for router in [
        RouterKind::RoundRobin,
        RouterKind::LeastLoaded,
        RouterKind::SizeAffinity { small_nodes: 2 },
        RouterKind::Sticky,
    ] {
        let s = spec(4, router);
        let r = Bencher::new(&format!("cluster/4-nodes/{}", router.label()))
            .items_per_iter(n_events)
            .target(Duration::from_secs(1))
            .run(|| {
                std::hint::black_box(run_cluster(&trace, &s));
            });
        println!("{r}");
    }

    group("cluster: migration/controller overhead (4 nodes, least-loaded)");
    {
        let base = spec(4, RouterKind::LeastLoaded);
        let variants: [(&str, ClusterSpec); 3] = [
            ("static", base.clone()),
            ("migrate", base.clone().with_migration(15_000)),
            (
                "migrate+ctl",
                base.with_migration(15_000).with_controller(ControllerConfig::default()),
            ),
        ];
        for (label, s) in &variants {
            let r = Bencher::new(&format!("cluster/4-nodes/{label}"))
                .items_per_iter(n_events)
                .target(Duration::from_secs(1))
                .run(|| {
                    std::hint::black_box(run_cluster(&trace, s));
                });
            println!("{r}");
        }
    }

    group("cluster: topology/churn overhead (4 nodes, least-loaded)");
    {
        let base = spec(4, RouterKind::LeastLoaded).with_migration(15_000);
        let variants: [(&str, ClusterSpec); 3] = [
            ("flat", base.clone()),
            ("ring-2ms", base.clone().with_topology(Topology::Ring { hop_us: 2_000 })),
            (
                "ring-2ms+churn",
                base.with_topology(Topology::Ring { hop_us: 2_000 }).with_churn(ChurnConfig {
                    seed: 11,
                    mean_up_us: 120_000_000, // ~7 failures/node over 15 min
                    mean_down_us: 20_000_000,
                }),
            ),
        ];
        for (label, s) in &variants {
            let r = Bencher::new(&format!("cluster/4-nodes/{label}"))
                .items_per_iter(n_events)
                .target(Duration::from_secs(1))
                .run(|| {
                    std::hint::black_box(run_cluster(&trace, s));
                });
            println!("{r}");
        }
    }

    group("cluster: multi-trial sweep across std::thread (8 seeds, 4 nodes)");
    let seeds: Vec<u64> = (0..8).map(|i| 100 + i).collect();

    // Serial reference.
    let t0 = Instant::now();
    let mut serial_events = 0u64;
    for &seed in &seeds {
        let trace = synthesize(&bench_workload(seed));
        serial_events += trace.events.len() as u64;
        std::hint::black_box(run_cluster(&trace, &spec(4, RouterKind::LeastLoaded)));
    }
    let serial = t0.elapsed();

    // One thread per trial (synthesis + simulation both inside).
    let t0 = Instant::now();
    let handles: Vec<_> = seeds
        .iter()
        .map(|&seed| {
            std::thread::spawn(move || {
                let trace = synthesize(&bench_workload(seed));
                let report = run_cluster(&trace, &spec(4, RouterKind::LeastLoaded));
                (trace.events.len() as u64, report.report.overall.cold_start_pct())
            })
        })
        .collect();
    let mut parallel_events = 0u64;
    for h in handles {
        let (events, cold_pct) = h.join().expect("trial thread panicked");
        parallel_events += events;
        std::hint::black_box(cold_pct);
    }
    let parallel = t0.elapsed();
    assert_eq!(serial_events, parallel_events, "trials must be deterministic");

    let rate = |events: u64, d: Duration| events as f64 / d.as_secs_f64() / 1e6;
    println!(
        "  serial:   {serial_events} events in {serial:?} ({:.2} M events/s)",
        rate(serial_events, serial)
    );
    println!(
        "  threaded: {parallel_events} events in {parallel:?} ({:.2} M events/s, {:.2}x)",
        rate(parallel_events, parallel),
        serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9)
    );
}
