//! The determinism-contract rule catalog (D01–D05) and its engine.
//!
//! Scope model: rules bind *non-test* code (`#[cfg(test)]` items are
//! skipped — the contract protects simulation results, and test-local
//! scaffolding cannot change them). D01/D02/D04 apply to the
//! determinism-critical module set (`sim/`, `trace/`, `metrics/`,
//! `coordinator/`, `config/`); D03 applies everywhere *except* the
//! wall-clock-legitimate surfaces (`bench/`, `serve/`, `runtime/`,
//! `main.rs`); D05 is a crate-wide structural check.
//!
//! Escape hatch: `// simlint: allow(Dxx) — reason` on the offending
//! line or the line directly above suppresses that rule there. The
//! reason is mandatory — a reasonless directive is itself a finding
//! (D00) and suppresses nothing. D05 findings anchor to declarations,
//! not use sites, and are baseline-only by design.

use crate::diag::Diagnostic;
use crate::lexer::{Comment, Lexed, TokKind};

/// One catalog entry: what a rule means and why it exists.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    /// Stable id (`"D01"`); allow directives and baselines name it.
    pub id: &'static str,
    /// One-line summary.
    pub title: &'static str,
    /// Why the rule exists / what to use instead.
    pub rationale: &'static str,
}

/// The rule catalog, in id order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D00",
        title: "malformed simlint directive",
        rationale: "an allow needs a known rule id and a written reason; a reasonless \
                    allow suppresses nothing",
    },
    RuleInfo {
        id: "D01",
        title: "unordered std hash containers on determinism-critical paths",
        rationale: "std::collections::{HashMap,HashSet} iterate in unspecified order; \
                    use BTreeMap/BTreeSet or the fxhash-indexed patterns (util::fxhash)",
    },
    RuleInfo {
        id: "D02",
        title: "unstable sorts on arrival/event/record streams",
        rationale: "sort_unstable* may reorder equal elements — the PR-6 same-microsecond \
                    tie-order incident; use the stable sort* family",
    },
    RuleInfo {
        id: "D03",
        title: "wall clock or OS entropy on simulation paths",
        rationale: "simulation time is virtual and randomness is seeded (util::rng::Pcg64); \
                    real clocks/entropy belong only in bench/, serve/, runtime/, main.rs",
    },
    RuleInfo {
        id: "D04",
        title: "float keys or float comparisons inside ordering comparators",
        rationale: "float comparators (partial_cmp, f32/f64 keys) are partial and \
                    platform-sensitive; order by integers (cross-multiplied if needed)",
    },
    RuleInfo {
        id: "D05",
        title: "RecordKind/Counters coverage drift across files",
        rationale: "every RecordKind variant must be dispatched in metrics and produced in \
                    sim/, and every Counters field must be merged — else reports silently \
                    drop data",
    },
];

/// Whether `id` names a catalog rule.
pub fn is_known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// Module prefixes (relative to the scan root) bound by D01/D02/D04.
pub const CRITICAL_PREFIXES: &[&str] = &["sim/", "trace/", "metrics/", "coordinator/", "config/"];

/// Module prefixes exempt from D03 (real time is their job: harness
/// timing, live serving, PJRT payload execution) plus the CLI entry.
pub const CLOCK_EXEMPT_PREFIXES: &[&str] = &["bench/", "serve/", "runtime/"];

fn in_critical_set(rel: &str) -> bool {
    CRITICAL_PREFIXES.iter().any(|p| rel.starts_with(p))
}

fn clock_exempt(rel: &str) -> bool {
    CLOCK_EXEMPT_PREFIXES.iter().any(|p| rel.starts_with(p)) || rel == "main.rs"
}

const D01_TYPES: &[&str] = &["HashMap", "HashSet"];
const D03_IDENTS: &[&str] = &[
    "Instant",
    "SystemTime",
    "RandomState",
    "OsRng",
    "ThreadRng",
    "thread_rng",
    "getrandom",
    "from_entropy",
];
const D04_COMPARATORS: &[&str] = &[
    "sort_by",
    "sort_by_key",
    "sort_unstable_by",
    "sort_unstable_by_key",
    "min_by",
    "min_by_key",
    "max_by",
    "max_by_key",
    "binary_search_by",
    "binary_search_by_key",
];

/// One parsed source file, ready for rule passes.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the scan root, forward slashes.
    pub rel: String,
    /// Raw source lines (diag snippets, baseline matching).
    pub lines: Vec<String>,
    /// Lexed tokens + line comments.
    pub lexed: Lexed,
    /// Token-index ranges (end-exclusive) covered by `#[cfg(test)]` /
    /// `#[test]` items.
    test_spans: Vec<(usize, usize)>,
}

impl SourceFile {
    /// Lex `src` and precompute its test-item spans.
    pub fn parse(rel: &str, src: &str) -> SourceFile {
        let lexed = crate::lexer::lex(src);
        let test_spans = test_spans(&lexed);
        SourceFile {
            rel: rel.to_string(),
            lines: src.lines().map(str::to_string).collect(),
            lexed,
            test_spans,
        }
    }

    fn in_test(&self, idx: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| idx >= a && idx < b)
    }

    fn snippet(&self, line: u32) -> String {
        self.lines
            .get(line.saturating_sub(1) as usize)
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    }

    fn diag(&self, rule: &'static str, line: u32, message: String) -> Diagnostic {
        Diagnostic { rule, path: self.rel.clone(), line, message, snippet: self.snippet(line) }
    }
}

/// Find the token index of the matching closer for the opener at
/// `open` (`{`/`}`, `(`/`)`, `[`/`]`). Returns the index *of* the
/// closer, or the last token when unbalanced.
fn match_delim(lexed: &Lexed, open: usize, oc: char, cc: char) -> usize {
    let mut depth = 0i64;
    for (i, t) in lexed.toks.iter().enumerate().skip(open) {
        if t.is_punct(oc) {
            depth += 1;
        } else if t.is_punct(cc) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
    }
    lexed.toks.len().saturating_sub(1)
}

/// Token-index spans of items annotated `#[cfg(test)]` (or `#[test]`,
/// `#[cfg(all(test, ...))]` — any attribute mentioning `test` without
/// `not`). The span runs from the attribute to the end of the item
/// body (`{...}`) or its terminating `;`.
fn test_spans(lexed: &Lexed) -> Vec<(usize, usize)> {
    let toks = &lexed.toks;
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if !(toks[i].is_punct('#') && toks[i + 1].is_punct('[')) {
            i += 1;
            continue;
        }
        let close = match_delim(lexed, i + 1, '[', ']');
        let attr = &toks[i + 2..close];
        let mentions = |s: &str| attr.iter().any(|t| t.is_ident(s));
        if !(mentions("test") && !mentions("not")) {
            i = close + 1;
            continue;
        }
        // Skip any further attributes between the marker and the item.
        let mut j = close + 1;
        while j + 1 < toks.len() && toks[j].is_punct('#') && toks[j + 1].is_punct('[') {
            j = match_delim(lexed, j + 1, '[', ']') + 1;
        }
        // Find the item body `{...}` (or a `;` declaration) at nesting
        // depth zero of parens/brackets.
        let mut pdepth = 0i64;
        let mut end = toks.len().saturating_sub(1);
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('(') || t.is_punct('[') {
                pdepth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                pdepth -= 1;
            } else if t.is_punct('{') {
                end = match_delim(lexed, j, '{', '}');
                break;
            } else if t.is_punct(';') && pdepth == 0 {
                end = j;
                break;
            }
            j += 1;
        }
        spans.push((i, end + 1));
        i = end + 1;
    }
    spans
}

/// A parsed `// simlint: allow(Dxx) — reason` directive.
#[derive(Clone, Debug)]
struct Directive {
    line: u32,
    rule: String,
}

/// Parse directives out of a file's line comments. Returns the valid
/// directives and a D00 diagnostic for each malformed one.
fn parse_directives(
    file: &SourceFile,
    comments: &[Comment],
) -> (Vec<Directive>, Vec<Diagnostic>) {
    let mut dirs = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        let body = c.text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("simlint:") else { continue };
        let rest = rest.trim();
        let parsed = rest.strip_prefix("allow(").and_then(|r| {
            let (id, tail) = r.split_once(')')?;
            let id = id.trim();
            if !is_known_rule(id) {
                return None;
            }
            let reason = tail
                .trim_start_matches(|ch: char| {
                    ch.is_whitespace() || matches!(ch, '—' | '–' | '-' | ':' | ',')
                })
                .trim();
            if reason.is_empty() {
                return None;
            }
            Some(id.to_string())
        });
        match parsed {
            Some(rule) => dirs.push(Directive { line: c.line, rule }),
            None => bad.push(file.diag(
                "D00",
                c.line,
                format!(
                    "malformed simlint directive `{}` — expected \
                     `simlint: allow(Dxx) — reason` with a known rule id and a \
                     non-empty reason (a reasonless allow suppresses nothing)",
                    body
                ),
            )),
        }
    }
    (dirs, bad)
}

/// Result of the per-file passes.
#[derive(Debug, Default)]
pub struct FileFindings {
    /// Diagnostics that survived allow-directive suppression.
    pub diags: Vec<Diagnostic>,
    /// How many diagnostics a reasoned allow suppressed.
    pub suppressed_allows: usize,
}

/// Run the single-file rules (D00–D04) over `file` and apply the
/// allow escape hatch.
pub fn check_file(file: &SourceFile) -> FileFindings {
    let mut raw: Vec<Diagnostic> = Vec::new();
    let toks = &file.lexed.toks;

    if in_critical_set(&file.rel) {
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Ident || file.in_test(i) {
                continue;
            }
            if D01_TYPES.contains(&t.text.as_str()) {
                raw.push(file.diag(
                    "D01",
                    t.line,
                    format!(
                        "`{}` iterates in unspecified order on a determinism-critical \
                         path — use BTreeMap/BTreeSet or util::fxhash::Fx{}",
                        t.text, t.text
                    ),
                ));
            }
            if t.text.starts_with("sort_unstable") {
                raw.push(file.diag(
                    "D02",
                    t.line,
                    format!(
                        "`{}` may reorder equal elements (the PR-6 same-microsecond \
                         tie-order incident) — use the stable sort* family",
                        t.text
                    ),
                ));
            }
        }
        raw.extend(d04_float_comparators(file));
    }

    if !clock_exempt(&file.rel) {
        for (i, t) in toks.iter().enumerate() {
            if t.kind == TokKind::Ident
                && !file.in_test(i)
                && D03_IDENTS.contains(&t.text.as_str())
            {
                raw.push(file.diag(
                    "D03",
                    t.line,
                    format!(
                        "wall-clock/OS-entropy source `{}` outside bench/, serve/, \
                         runtime/, main.rs — simulation time is virtual and randomness \
                         is seeded (util::rng::Pcg64)",
                        t.text
                    ),
                ));
            }
        }
    }

    let (dirs, bad_dirs) = parse_directives(file, &file.lexed.comments);
    let allowed = |d: &Diagnostic| {
        dirs.iter()
            .any(|a| a.rule == d.rule && (a.line == d.line || a.line + 1 == d.line))
    };
    let mut out = FileFindings::default();
    for d in raw {
        if allowed(&d) {
            out.suppressed_allows += 1;
        } else {
            out.diags.push(d);
        }
    }
    out.diags.extend(bad_dirs);
    out
}

/// D04: flag `f32`/`f64`/`partial_cmp`/float literals inside the
/// argument list of an ordering-comparator call (`.sort_by(...)`,
/// `.min_by_key(...)`, ...).
fn d04_float_comparators(file: &SourceFile) -> Vec<Diagnostic> {
    let toks = &file.lexed.toks;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        let call = toks[i].is_punct('.')
            && toks[i + 1].kind == TokKind::Ident
            && D04_COMPARATORS.contains(&toks[i + 1].text.as_str())
            && toks[i + 2].is_punct('(');
        if !call || file.in_test(i) {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        let close = match_delim(&file.lexed, i + 2, '(', ')');
        for t in &toks[i + 3..close] {
            let offending = match t.kind {
                TokKind::Ident => matches!(t.text.as_str(), "f32" | "f64" | "partial_cmp"),
                TokKind::Num { float } => float,
                _ => false,
            };
            if offending {
                out.push(file.diag(
                    "D04",
                    t.line,
                    format!(
                        "float ordering inside `.{}(...)` (`{}`): comparators on sim \
                         paths must order by integers — floats are partial and \
                         platform-sensitive",
                        name,
                        if t.text.is_empty() { "float" } else { &t.text }
                    ),
                ));
                break; // one finding per comparator call
            }
        }
        i = close + 1;
    }
    out
}

/// Run the crate-wide structural rule (D05) over all files.
///
/// D05a: every `RecordKind` variant must be referenced
/// (`RecordKind::Variant`) in `metrics/mod.rs` outside the enum
/// definition (the dispatch/merge side) *and* somewhere under `sim/`
/// (the producer side).
/// D05b: every named field of `struct Counters` must appear inside
/// `Counters::merge` — a field missing from the merge silently breaks
/// sharded report merging and the `overall = small + large` invariant.
///
/// Vacuously passes when the scanned tree has no
/// `metrics/mod.rs` with a `RecordKind` enum (the rule is specific to
/// this crate's report pipeline).
pub fn check_crate(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let Some(metrics) = files
        .iter()
        .find(|f| f.rel == "metrics/mod.rs" || f.rel.ends_with("/metrics/mod.rs"))
    else {
        return out;
    };

    if let Some((variants, def_span)) = parse_enum_variants(metrics, "RecordKind") {
        for (name, line) in &variants {
            let in_metrics = has_variant_usage(metrics, name, Some(def_span));
            let in_sim = files
                .iter()
                .filter(|f| f.rel.starts_with("sim/") || f.rel.contains("/sim/"))
                .any(|f| has_variant_usage(f, name, None));
            if !in_metrics {
                out.push(metrics.diag(
                    "D05",
                    *line,
                    format!(
                        "RecordKind::{name} is never dispatched in metrics/mod.rs \
                         outside its definition — wire it through Report::record (and \
                         the counter it feeds) before shipping the variant"
                    ),
                ));
            }
            if !in_sim {
                out.push(metrics.diag(
                    "D05",
                    *line,
                    format!(
                        "RecordKind::{name} is never produced under sim/ — dead \
                         variant, or its recording site is missing"
                    ),
                ));
            }
        }
    }

    if let Some((fields, struct_line)) = parse_struct_fields(metrics, "Counters") {
        match fn_body_span(metrics, "merge") {
            Some((a, b)) => {
                for (name, line) in &fields {
                    let merged = metrics.lexed.toks[a..b]
                        .iter()
                        .any(|t| t.is_ident(name));
                    if !merged {
                        out.push(metrics.diag(
                            "D05",
                            *line,
                            format!(
                                "Counters::{name} is missing from Counters::merge — \
                                 sharded report merging and the overall = small + large \
                                 consistency check would silently drop it"
                            ),
                        ));
                    }
                }
            }
            None => out.push(metrics.diag(
                "D05",
                struct_line,
                "struct Counters has no merge fn — report merging cannot cover its \
                 fields"
                    .to_string(),
            )),
        }
    }
    out
}

/// Parse the variant list of `enum <name> { ... }` in `file`,
/// returning `(variants, (body_open_idx, body_close_idx))`.
fn parse_enum_variants(
    file: &SourceFile,
    name: &str,
) -> Option<(Vec<(String, u32)>, (usize, usize))> {
    let toks = &file.lexed.toks;
    let at = (0..toks.len().saturating_sub(2)).find(|&i| {
        toks[i].is_ident("enum") && toks[i + 1].is_ident(name) && !file.in_test(i)
    })?;
    let open = (at + 2..toks.len()).find(|&i| toks[i].is_punct('{'))?;
    let close = match_delim(&file.lexed, open, '{', '}');
    let mut variants = Vec::new();
    let mut i = open + 1;
    while i < close {
        let t = &toks[i];
        // Skip attributes on variants.
        if t.is_punct('#') && toks.get(i + 1).is_some_and(|n| n.is_punct('[')) {
            i = match_delim(&file.lexed, i + 1, '[', ']') + 1;
            continue;
        }
        if t.kind == TokKind::Ident && t.text.chars().next().is_some_and(char::is_uppercase) {
            variants.push((t.text.clone(), t.line));
            // Skip the payload / discriminant to the next separator.
            i += 1;
            while i < close {
                if toks[i].is_punct('{') {
                    i = match_delim(&file.lexed, i, '{', '}') + 1;
                } else if toks[i].is_punct('(') {
                    i = match_delim(&file.lexed, i, '(', ')') + 1;
                } else if toks[i].is_punct(',') {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        i += 1;
    }
    Some((variants, (open, close)))
}

/// Whether `file` references `RecordKind::<variant>` in non-test code,
/// outside `exclude` (the enum's own definition span).
fn has_variant_usage(file: &SourceFile, variant: &str, exclude: Option<(usize, usize)>) -> bool {
    let toks = &file.lexed.toks;
    (0..toks.len().saturating_sub(3)).any(|i| {
        toks[i].is_ident("RecordKind")
            && toks[i + 1].is_punct(':')
            && toks[i + 2].is_punct(':')
            && toks[i + 3].is_ident(variant)
            && !file.in_test(i)
            && match exclude {
                Some((a, b)) => i < a || i > b,
                None => true,
            }
    })
}

/// Parse the named-field list of `struct <name> { ... }`, returning
/// `(fields, struct_line)`.
fn parse_struct_fields(file: &SourceFile, name: &str) -> Option<(Vec<(String, u32)>, u32)> {
    let toks = &file.lexed.toks;
    let at = (0..toks.len().saturating_sub(2)).find(|&i| {
        toks[i].is_ident("struct") && toks[i + 1].is_ident(name) && !file.in_test(i)
    })?;
    let open = (at + 2..toks.len()).find(|&i| toks[i].is_punct('{'))?;
    let close = match_delim(&file.lexed, open, '{', '}');
    let mut fields = Vec::new();
    let mut i = open + 1;
    while i < close {
        let t = &toks[i];
        if t.is_punct('#') && toks.get(i + 1).is_some_and(|n| n.is_punct('[')) {
            i = match_delim(&file.lexed, i + 1, '[', ']') + 1;
            continue;
        }
        if t.is_ident("pub") {
            // Skip `pub` and a possible `(crate)` restriction.
            i += 1;
            if i < close && toks[i].is_punct('(') {
                i = match_delim(&file.lexed, i, '(', ')') + 1;
            }
            continue;
        }
        if t.kind == TokKind::Ident && toks.get(i + 1).is_some_and(|n| n.is_punct(':')) {
            fields.push((t.text.clone(), t.line));
            // Skip the type to the next top-level comma.
            let mut depth = 0i64;
            i += 2;
            while i < close {
                let x = &toks[i];
                if x.is_punct('<') || x.is_punct('(') || x.is_punct('[') {
                    depth += 1;
                } else if x.is_punct('>') || x.is_punct(')') || x.is_punct(']') {
                    depth -= 1;
                } else if x.is_punct(',') && depth <= 0 {
                    i += 1;
                    break;
                }
                i += 1;
            }
            continue;
        }
        i += 1;
    }
    Some((fields, toks[at].line))
}

/// Token span `(start, end)` of the body of the first non-test
/// `fn <name>` in `file`.
fn fn_body_span(file: &SourceFile, name: &str) -> Option<(usize, usize)> {
    let toks = &file.lexed.toks;
    let at = (0..toks.len().saturating_sub(1)).find(|&i| {
        toks[i].is_ident("fn") && toks[i + 1].is_ident(name) && !file.in_test(i)
    })?;
    let open = (at + 2..toks.len()).find(|&i| toks[i].is_punct('{'))?;
    Some((open, match_delim(&file.lexed, open, '{', '}')))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_spans_cover_cfg_test_modules_but_not_cfg_not_test() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\n\
                   #[cfg(not(test))]\nfn also_live() {}\n";
        let f = SourceFile::parse("sim/x.rs", src);
        let helper = f
            .lexed
            .toks
            .iter()
            .position(|t| t.is_ident("helper"))
            .unwrap();
        let live = f
            .lexed
            .toks
            .iter()
            .position(|t| t.is_ident("also_live"))
            .unwrap();
        assert!(f.in_test(helper));
        assert!(!f.in_test(live));
    }

    #[test]
    fn enum_and_struct_parsers_handle_payloads_and_attrs() {
        let src = "pub enum RecordKind {\n    Hit,\n    #[allow(dead_code)]\n    \
                   Migrate { donor: usize, recipient: usize },\n    Off(u64),\n}\n\
                   pub struct Counters {\n    pub hits: u64,\n    pub latency: Vec<(u64, u64)>,\n}\n";
        let f = SourceFile::parse("metrics/mod.rs", src);
        let (variants, _) = parse_enum_variants(&f, "RecordKind").unwrap();
        let names: Vec<&str> = variants.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["Hit", "Migrate", "Off"]);
        let (fields, _) = parse_struct_fields(&f, "Counters").unwrap();
        let fnames: Vec<&str> = fields.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(fnames, ["hits", "latency"]);
    }

    #[test]
    fn directives_require_reasons_and_known_rules() {
        let src = "// simlint: allow(D02) — integer keys, ties indistinguishable\n\
                   // simlint: allow(D02)\n// simlint: allow(D99) — nope\n// plain comment\n";
        let f = SourceFile::parse("sim/x.rs", src);
        let (dirs, bad) = parse_directives(&f, &f.lexed.comments);
        assert_eq!(dirs.len(), 1);
        assert_eq!(dirs[0].rule, "D02");
        assert_eq!(bad.len(), 2, "{bad:?}");
        assert!(bad.iter().all(|d| d.rule == "D00"));
    }
}
