//! # simlint — the determinism-contract static-analysis pass
//!
//! Every guarantee the `kiss-faas` crate sells — KiSS-vs-baseline
//! comparisons, the bit-for-bit equivalence locks, the Mode-A sharded
//! kernel — rests on a determinism contract that used to be folklore.
//! This tool makes it an artifact: a typed rule catalog
//! ([`rules::RULES`], D01–D05) enforced over the determinism-critical
//! module set, with an inline escape hatch
//! (`// simlint: allow(Dxx) — reason`) and a committed [`baseline`]
//! for grandfathered sites.
//!
//! Run it from the `rust/` workspace as
//! `cargo run -p simlint -- check src`, or from the repository root as
//! `cargo run --manifest-path rust/Cargo.toml -p simlint -- check rust/src`.
//!
//! ## Why not `syn`?
//!
//! The build container is offline — the root crate vendors every
//! substrate it needs (its "Offline-environment note"), and this pass
//! follows suit: a ~300-line lexer ([`lexer`]) produces exactly the
//! token structure the rules need (whole identifiers, float-flagged
//! literals, comment/string stripping, `#[cfg(test)]` spans). The
//! trade-off is deliberate: rules match tokens and small token
//! patterns, not resolved paths, so `use std::collections::HashMap as
//! Map` could smuggle a name past D01 — but that rename would itself
//! never survive review, and the cheap lexical layer is backstopped by
//! `clippy.toml` `disallowed-types`/`disallowed-methods` (which *does*
//! resolve paths) plus the Miri/TSan CI job for the dynamic side.

pub mod baseline;
pub mod diag;
pub mod lexer;
pub mod rules;

use std::io;
use std::path::{Path, PathBuf};

pub use baseline::Baseline;
pub use diag::Diagnostic;
pub use rules::SourceFile;

/// Result of checking a source tree.
#[derive(Debug, Default)]
pub struct CheckOutcome {
    /// Findings that survived allow + baseline suppression, sorted by
    /// `(path, line, rule)`.
    pub diagnostics: Vec<Diagnostic>,
    /// Findings suppressed by a reasoned `// simlint: allow(...)`.
    pub suppressed_allows: usize,
    /// Findings suppressed by the baseline.
    pub suppressed_baseline: usize,
    /// Baseline entries that covered nothing (stale).
    pub unused_baseline: Vec<baseline::Entry>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl CheckOutcome {
    /// Whether the tree is clean (exit code 0).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Recursively collect `.rs` files under `root`, sorted by relative
/// path for deterministic output.
fn collect_rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Forward-slash path of `path` relative to `root`.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Check every `.rs` file under `root` against the rule catalog,
/// applying `baseline` (if any) after inline allows.
pub fn check_root(root: &Path, baseline: Option<&Baseline>) -> io::Result<CheckOutcome> {
    let mut files = Vec::new();
    for path in collect_rs_files(root)? {
        let src = std::fs::read_to_string(&path)?;
        files.push(SourceFile::parse(&rel_path(root, &path), &src));
    }

    let mut outcome = CheckOutcome { files_scanned: files.len(), ..Default::default() };
    let mut raw: Vec<Diagnostic> = Vec::new();
    for f in &files {
        let findings = rules::check_file(f);
        outcome.suppressed_allows += findings.suppressed_allows;
        raw.extend(findings.diags);
    }
    raw.extend(rules::check_crate(&files));

    if let Some(b) = baseline {
        outcome.unused_baseline = b.unused(&raw).into_iter().cloned().collect();
        for d in raw {
            if b.covers(&d) {
                outcome.suppressed_baseline += 1;
            } else {
                outcome.diagnostics.push(d);
            }
        }
    } else {
        outcome.diagnostics = raw;
    }
    outcome
        .diagnostics
        .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_are_unique_and_complete() {
        let ids: Vec<&str> = rules::RULES.iter().map(|r| r.id).collect();
        assert_eq!(ids, ["D00", "D01", "D02", "D03", "D04", "D05"]);
        let mut dedup = ids.clone();
        dedup.dedup();
        assert_eq!(ids, dedup);
    }
}
