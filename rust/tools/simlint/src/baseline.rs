//! The committed baseline: grandfathered diagnostics that do not fail
//! the build (yet).
//!
//! Format — one entry per line, tab-separated:
//!
//! ```text
//! RULE<TAB>path/relative/to/scan-root.rs<TAB>trimmed source line
//! ```
//!
//! `#` comments and blank lines are ignored. Matching is on
//! `(rule, path, trimmed-line-content)` — *not* on line numbers — so
//! unrelated edits above a grandfathered site do not invalidate it,
//! while any edit to the offending line itself un-grandfathers it.
//! Entries that matched nothing are reported as stale so the file can
//! only shrink.

use std::fmt::Write as _;
use std::path::Path;

use crate::diag::Diagnostic;

/// One baseline entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    /// Rule id the entry grandfathers.
    pub rule: String,
    /// Scan-root-relative path, forward slashes.
    pub path: String,
    /// Trimmed source line of the grandfathered site.
    pub snippet: String,
}

/// A parsed baseline file.
#[derive(Clone, Debug, Default)]
pub struct Baseline {
    /// Entries in file order.
    pub entries: Vec<Entry>,
}

impl Baseline {
    /// Parse baseline text. Lines that are neither comments, blank,
    /// nor three tab-separated fields are returned as errors.
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(3, '\t');
            match (parts.next(), parts.next(), parts.next()) {
                (Some(rule), Some(path), Some(snippet)) if !rule.is_empty() => {
                    entries.push(Entry {
                        rule: rule.to_string(),
                        path: path.to_string(),
                        snippet: snippet.to_string(),
                    });
                }
                _ => {
                    return Err(format!(
                        "baseline line {}: expected `RULE\\tpath\\tsnippet`, got `{}`",
                        i + 1,
                        line
                    ))
                }
            }
        }
        Ok(Baseline { entries })
    }

    /// Load a baseline from `path`.
    pub fn load(path: &Path) -> Result<Baseline, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read baseline {}: {}", path.display(), e))?;
        Self::parse(&text)
    }

    /// Whether `d` is grandfathered by some entry.
    pub fn covers(&self, d: &Diagnostic) -> bool {
        self.entries
            .iter()
            .any(|e| e.rule == d.rule && e.path == d.path && e.snippet == d.snippet)
    }

    /// Entries that cover none of `diags` (stale — candidates for
    /// deletion; the baseline should only ever shrink).
    pub fn unused<'a>(&'a self, diags: &[Diagnostic]) -> Vec<&'a Entry> {
        self.entries
            .iter()
            .filter(|e| {
                !diags
                    .iter()
                    .any(|d| e.rule == d.rule && e.path == d.path && e.snippet == d.snippet)
            })
            .collect()
    }
}

/// Render `diags` as baseline text (`--write-baseline`).
pub fn render(diags: &[Diagnostic]) -> String {
    let mut s = String::from(
        "# simlint baseline — grandfathered diagnostics (see rust/tools/simlint).\n\
         # Format: RULE<TAB>path<TAB>trimmed source line. Keep this file shrinking:\n\
         # fix the site or carry an inline `// simlint: allow(Dxx) — reason` instead.\n",
    );
    for d in diags {
        let _ = writeln!(s, "{}\t{}\t{}", d.rule, d.path, d.snippet);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(rule: &'static str, path: &str, snippet: &str) -> Diagnostic {
        Diagnostic {
            rule,
            path: path.into(),
            line: 1,
            message: String::new(),
            snippet: snippet.into(),
        }
    }

    #[test]
    fn parse_cover_unused_roundtrip() {
        let text = "# header\nD01\tsim/a.rs\tuse std::collections::HashMap;\n\
                    D02\tsim/b.rs\tv.sort_unstable();\n";
        let b = Baseline::parse(text).unwrap();
        assert_eq!(b.entries.len(), 2);
        let hit = diag("D01", "sim/a.rs", "use std::collections::HashMap;");
        let miss = diag("D01", "sim/a.rs", "use std::collections::HashSet;");
        assert!(b.covers(&hit));
        assert!(!b.covers(&miss));
        let unused = b.unused(std::slice::from_ref(&hit));
        assert_eq!(unused.len(), 1);
        assert_eq!(unused[0].rule, "D02");
        // render -> parse keeps the entries.
        let again = Baseline::parse(&render(&[hit.clone()])).unwrap();
        assert!(again.covers(&hit));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Baseline::parse("D01 only-two-fields\n").is_err());
    }
}
