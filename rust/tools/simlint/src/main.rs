//! CLI for the determinism-contract pass.
//!
//! ```text
//! simlint check <root> [--format text|json] [--baseline FILE | --no-baseline]
//!                      [--write-baseline FILE] [--quiet]
//! simlint rules
//! ```
//!
//! Exit codes: 0 clean, 1 diagnostics found, 2 usage or I/O error.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use simlint::{baseline, check_root, diag, rules, Baseline};

const USAGE: &str = "\
simlint — determinism-contract static analysis for kiss-faas

USAGE:
    simlint check <root> [OPTIONS]    lint every .rs file under <root>
    simlint rules                     print the rule catalog

OPTIONS (check):
    --format <text|json>      output format (default: text)
    --baseline <FILE>         baseline file (default: <root>/../tools/simlint/baseline.txt
                              when it exists)
    --no-baseline             ignore any baseline
    --write-baseline <FILE>   write surviving diagnostics as a new baseline and exit 0
    --quiet                   suppress the summary line on success

Diagnostics are suppressed by `// simlint: allow(Dxx) — reason` on the
offending line or the line above (reason mandatory), or by a baseline
entry; see `simlint rules` for the catalog.";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("rules") => {
            for r in rules::RULES {
                println!("{}  {}\n     {}", r.id, r.title, r.rationale);
            }
            ExitCode::SUCCESS
        }
        Some("check") => run_check(&args[1..]),
        _ => {
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

struct Opts {
    root: PathBuf,
    format: String,
    baseline: Option<PathBuf>,
    no_baseline: bool,
    write_baseline: Option<PathBuf>,
    quiet: bool,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::new(),
        format: "text".to_string(),
        baseline: None,
        no_baseline: false,
        write_baseline: None,
        quiet: false,
    };
    let mut root = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut value = |flag: &str| {
            it.next()
                .cloned()
                .ok_or_else(|| format!("{flag} needs a value"))
        };
        match a.as_str() {
            "--format" => opts.format = value("--format")?,
            "--baseline" => opts.baseline = Some(PathBuf::from(value("--baseline")?)),
            "--no-baseline" => opts.no_baseline = true,
            "--write-baseline" => {
                opts.write_baseline = Some(PathBuf::from(value("--write-baseline")?))
            }
            "--quiet" => opts.quiet = true,
            flag if flag.starts_with("--") => return Err(format!("unknown flag {flag}")),
            path if root.is_none() => root = Some(PathBuf::from(path)),
            extra => return Err(format!("unexpected argument {extra}")),
        }
    }
    if !matches!(opts.format.as_str(), "text" | "json") {
        return Err(format!("--format must be text or json, got {}", opts.format));
    }
    opts.root = root.ok_or("check needs a <root> directory")?;
    Ok(opts)
}

/// The default committed baseline location: `tools/simlint/baseline.txt`
/// next to the scanned source tree (so `check src` from `rust/` and
/// `check rust/src` from the repo root both find it).
fn default_baseline(root: &Path) -> Option<PathBuf> {
    let p = root.parent()?.join("tools/simlint/baseline.txt");
    p.exists().then_some(p)
}

fn run_check(args: &[String]) -> ExitCode {
    let opts = match parse_opts(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::from(2);
        }
    };
    if !opts.root.is_dir() {
        eprintln!("error: {} is not a directory", opts.root.display());
        return ExitCode::from(2);
    }

    let baseline = if opts.no_baseline {
        None
    } else {
        let path = opts.baseline.clone().or_else(|| default_baseline(&opts.root));
        match path {
            None => None,
            Some(p) => match Baseline::load(&p) {
                Ok(b) => Some(b),
                Err(e) => {
                    eprintln!("error: {e}");
                    return ExitCode::from(2);
                }
            },
        }
    };

    let outcome = match check_root(&opts.root, baseline.as_ref()) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };

    if let Some(out) = opts.write_baseline {
        let text = baseline::render(&outcome.diagnostics);
        if let Err(e) = std::fs::write(&out, text) {
            eprintln!("error: cannot write {}: {e}", out.display());
            return ExitCode::from(2);
        }
        println!(
            "wrote {} baseline entr{} to {}",
            outcome.diagnostics.len(),
            if outcome.diagnostics.len() == 1 { "y" } else { "ies" },
            out.display()
        );
        return ExitCode::SUCCESS;
    }

    if opts.format == "json" {
        print!("{}", diag::render_json(&outcome.diagnostics));
    } else {
        for d in &outcome.diagnostics {
            println!("{}", d.render_text());
        }
        for e in &outcome.unused_baseline {
            eprintln!(
                "note: stale baseline entry ({} {} `{}`) matched nothing — delete it",
                e.rule, e.path, e.snippet
            );
        }
        if !outcome.is_clean() {
            eprintln!(
                "simlint: {} diagnostic{} in {} file{} ({} allowed inline, {} baselined)",
                outcome.diagnostics.len(),
                if outcome.diagnostics.len() == 1 { "" } else { "s" },
                outcome.files_scanned,
                if outcome.files_scanned == 1 { "" } else { "s" },
                outcome.suppressed_allows,
                outcome.suppressed_baseline,
            );
        } else if !opts.quiet {
            println!(
                "simlint: clean — {} files, {} allowed inline, {} baselined, {} stale \
                 baseline entr{}",
                outcome.files_scanned,
                outcome.suppressed_allows,
                outcome.suppressed_baseline,
                outcome.unused_baseline.len(),
                if outcome.unused_baseline.len() == 1 { "y" } else { "ies" },
            );
        }
    }
    if outcome.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
