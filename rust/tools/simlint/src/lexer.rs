//! A minimal Rust lexer: just enough token structure for the D-rule
//! catalog, none of the grammar.
//!
//! The scanner produces whole identifiers, numeric literals (with a
//! float flag), single-character punctuation, and opaque string/char
//! tokens, each tagged with its 1-based source line. Comments and
//! string contents are stripped from the token stream — a `HashMap`
//! mentioned in rustdoc prose must never trip D01 — but `//` line
//! comments are collected separately so the `// simlint: allow(...)`
//! escape hatch can be parsed from them. Block comments cannot carry
//! directives.
//!
//! Deliberately *not* handled: macro expansion (rules see macro input
//! tokens as written, which is what a reviewer sees too) and exotic
//! literal suffixes beyond the usual `1_000u64` / `1.5f64` shapes.

/// Kind of one lexed token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// An identifier or keyword (`HashMap`, `fn`, `sort_unstable_by`).
    Ident,
    /// A numeric literal; `float` is true for `1.5`, `2e9`, `3f64`.
    Num {
        /// Whether the literal is floating-point.
        float: bool,
    },
    /// One punctuation character (`::` arrives as two adjacent `:`).
    Punct,
    /// A string, raw-string, byte-string, or char literal (content
    /// discarded — only position matters).
    Str,
    /// A lifetime (`'a`); kept distinct so it is never a char literal.
    Lifetime,
}

/// One token with its source position.
#[derive(Clone, Debug)]
pub struct Tok {
    /// What kind of token this is.
    pub kind: TokKind,
    /// The token text (empty for `Str` — contents are opaque).
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

impl Tok {
    /// Whether this token is the identifier `s`.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Whether this token is the punctuation character `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// A `//` line comment (directive candidates), with its source line.
#[derive(Clone, Debug)]
pub struct Comment {
    /// Full comment text including the leading slashes.
    pub text: String,
    /// 1-based line the comment starts on.
    pub line: u32,
}

/// The lexed view of one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    /// Tokens in source order.
    pub toks: Vec<Tok>,
    /// `//` comments in source order.
    pub comments: Vec<Comment>,
}

/// Lex `src` into tokens + line comments. Never fails: unrecognized
/// bytes become single `Punct` tokens, unterminated literals run to
/// end-of-file.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (collected for directive parsing).
        if c == '/' && b.get(i + 1) == Some(&'/') {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.comments
                .push(Comment { text: b[start..i].iter().collect(), line });
            continue;
        }
        // Block comment, nested per Rust's rules.
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: r"..", r#".."#, br#".."# with any # count.
        if let Some((len, newlines)) = raw_string_len(&b[i..]) {
            out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            line += newlines;
            i += len;
            continue;
        }
        // Plain or byte string literal.
        if c == '"' || (c == 'b' && b.get(i + 1) == Some(&'"')) {
            if c == 'b' {
                i += 1;
            }
            let start_line = line;
            i += 1; // opening quote
            while i < n {
                match b[i] {
                    '\\' => i += 2,
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            out.toks
                .push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
            continue;
        }
        // Lifetime vs char literal.
        if c == '\'' {
            let is_lifetime = matches!(b.get(i + 1), Some(x) if x.is_alphabetic() || *x == '_')
                && b.get(i + 2) != Some(&'\'');
            if is_lifetime {
                let start = i + 1;
                i += 1;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[start..i].iter().collect(),
                    line,
                });
            } else {
                i += 1; // opening quote
                while i < n {
                    match b[i] {
                        '\\' => i += 2,
                        '\'' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
                out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            }
            continue;
        }
        // Numeric literal.
        if c.is_ascii_digit() {
            let start = i;
            let mut float = false;
            while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                i += 1;
            }
            // Fractional part — but never eat `..` (range syntax).
            if i < n
                && b[i] == '.'
                && matches!(b.get(i + 1), Some(d) if d.is_ascii_digit())
            {
                float = true;
                i += 1;
                while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                    i += 1;
                }
            }
            // Exponent (`2e9`, `1.5e-3`).
            if i < n && (b[i] == 'e' || b[i] == 'E') {
                let sign = usize::from(matches!(b.get(i + 1), Some('+') | Some('-')));
                if matches!(b.get(i + 1 + sign), Some(d) if d.is_ascii_digit()) {
                    float = true;
                    i += 1 + sign;
                    while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                        i += 1;
                    }
                }
            }
            // Suffix (`u64`, `f32`); a float suffix makes it a float.
            let suffix_start = i;
            while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            let suffix: String = b[suffix_start..i].iter().collect();
            if suffix == "f32" || suffix == "f64" || text.ends_with("f32") || text.ends_with("f64")
            {
                float = true;
            }
            out.toks.push(Tok { kind: TokKind::Num { float }, text, line });
            continue;
        }
        // Identifier or keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Anything else: one punctuation char.
        out.toks
            .push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// If `rest` starts a raw (byte) string literal, return its total
/// length in chars and the number of newlines it spans.
fn raw_string_len(rest: &[char]) -> Option<(usize, u32)> {
    let mut j = 0usize;
    if rest.first() == Some(&'b') {
        j += 1;
    }
    if rest.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while rest.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if rest.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    let mut newlines = 0u32;
    while j < rest.len() {
        if rest[j] == '\n' {
            newlines += 1;
            j += 1;
            continue;
        }
        if rest[j] == '"' {
            let mut k = 0usize;
            while k < hashes && rest.get(j + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                return Some((j + 1 + hashes, newlines));
            }
        }
        j += 1;
    }
    Some((rest.len(), newlines)) // unterminated: runs to EOF
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_identifiers() {
        let src = r##"
// HashMap in a line comment
/* HashMap in /* a nested */ block */
let s = "HashMap in a string";
let r = r#"HashMap raw "quoted" text"#;
let c = 'h';
"##;
        let ids = idents(src);
        assert!(!ids.iter().any(|t| t == "HashMap"), "{ids:?}");
        assert!(ids.iter().any(|t| t == "let"));
    }

    #[test]
    fn line_comments_are_collected_with_lines() {
        let lexed = lex("let a = 1;\n// simlint: allow(D01) — why\nlet b = 2;\n");
        assert_eq!(lexed.comments.len(), 1);
        assert_eq!(lexed.comments[0].line, 2);
        assert!(lexed.comments[0].text.contains("allow(D01)"));
        assert_eq!(lexed.toks.last().unwrap().line, 3);
    }

    #[test]
    fn float_literals_are_flagged_ranges_are_not() {
        let lexed = lex("a[0..10]; x = 1.5; y = 2e9; z = 3f64; n = 1_000u64;");
        let nums: Vec<(String, bool)> = lexed
            .toks
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Num { float } => Some((t.text, float)),
                _ => None,
            })
            .collect();
        assert_eq!(
            nums,
            vec![
                ("0".into(), false),
                ("10".into(), false),
                ("1.5".into(), true),
                ("2e9".into(), true),
                ("3f64".into(), true),
                ("1_000u64".into(), false),
            ]
        );
    }

    #[test]
    fn lifetimes_do_not_swallow_code() {
        let ids = idents("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(ids.iter().any(|t| t == "str"));
        assert!(ids.iter().any(|t| t == "fn"));
    }
}
