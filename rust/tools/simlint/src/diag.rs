//! Diagnostics: the unit of simlint output, with human `file:line`
//! text rendering and a machine-readable JSON rendering.

use std::fmt::Write as _;

/// One finding: a rule tripped at a source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Rule id (`"D01"`).
    pub rule: &'static str,
    /// Path of the offending file, relative to the scan root, with
    /// forward slashes (stable across platforms — baselines match on
    /// it).
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// What went wrong and what to do instead.
    pub message: String,
    /// The trimmed source line (baseline entries match on it, so a
    /// grandfathered site stops matching the moment it is edited).
    pub snippet: String,
}

impl Diagnostic {
    /// `path:line: RULE: message` — the human, grep-able form.
    pub fn render_text(&self) -> String {
        format!("{}:{}: {}: {}", self.path, self.line, self.rule, self.message)
    }
}

/// Render diagnostics as a JSON document:
/// `{"schema": "...", "count": N, "diagnostics": [...]}`.
///
/// Hand-rolled like the root crate's `util::json` — simlint carries
/// zero dependencies so the offline container can always build it.
pub fn render_json(diags: &[Diagnostic]) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"kiss-faas/simlint/v1\",\n");
    let _ = writeln!(s, "  \"count\": {},", diags.len());
    s.push_str("  \"diagnostics\": [");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    {");
        let _ = write!(s, "\"rule\": {}, ", json_str(d.rule));
        let _ = write!(s, "\"path\": {}, ", json_str(&d.path));
        let _ = write!(s, "\"line\": {}, ", d.line);
        let _ = write!(s, "\"message\": {}, ", json_str(&d.message));
        let _ = write!(s, "\"snippet\": {}", json_str(&d.snippet));
        s.push('}');
    }
    if !diags.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

/// Escape `v` as a JSON string literal.
fn json_str(v: &str) -> String {
    let mut s = String::with_capacity(v.len() + 2);
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s.push('"');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes_and_counts() {
        let d = Diagnostic {
            rule: "D01",
            path: "sim/x.rs".into(),
            line: 7,
            message: "say \"no\"".into(),
            snippet: "let m: HashMap<u32, u32>;".into(),
        };
        let j = render_json(std::slice::from_ref(&d));
        assert!(j.contains("\"count\": 1"));
        assert!(j.contains("\\\"no\\\""));
        assert!(j.contains("\"kiss-faas/simlint/v1\""));
        assert!(render_json(&[]).contains("\"count\": 0"));
    }
}
