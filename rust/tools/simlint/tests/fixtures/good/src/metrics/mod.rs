//! D05 fixture (passing): the only variant is dispatched here and
//! produced under sim/, and every Counters field is merged.
pub enum RecordKind {
    Hit,
}

pub struct Counters {
    pub hits: u64,
}

impl Counters {
    pub fn merge(&mut self, other: &Counters) {
        self.hits += other.hits;
    }
}

pub fn record(kind: RecordKind, c: &mut Counters) {
    match kind {
        RecordKind::Hit => c.hits += 1,
    }
}
