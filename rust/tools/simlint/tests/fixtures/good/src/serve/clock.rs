//! Wall-clock time is the serving path's job — D03 exempts serve/.
use std::time::Instant;

pub fn ms_since(t0: Instant) -> u128 {
    t0.elapsed().as_millis()
}
