//! Deterministic containers and stable sorts pass the catalog; the
//! fxhash-indexed pattern (`FxHashMap`) is explicitly allowed by D01.
use std::collections::BTreeMap;

pub struct ShareState {
    pub deflated: BTreeMap<(usize, u32), u64>,
    pub homes: crate::util::fxhash::FxHashMap<u32, usize>,
}

pub fn order(mut events: Vec<(u64, u32)>) -> Vec<(u64, u32)> {
    events.sort_by_key(|e| e.0);
    events
}
