//! The escape hatch: a reasoned allow on the line above (or the same
//! line) suppresses exactly that rule at that site.
pub fn dedup(mut xs: Vec<u64>) -> Vec<u64> {
    // simlint: allow(D02) — integer keys: equal elements are indistinguishable
    xs.sort_unstable();
    xs.dedup();
    xs
}
