//! The sim side produces every RecordKind variant (D05's cross-file
//! producer leg).
use crate::metrics::{record, Counters, RecordKind};

pub fn serve(c: &mut Counters) {
    record(RecordKind::Hit, c);
}
