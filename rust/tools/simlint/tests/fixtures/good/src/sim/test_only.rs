//! Test modules are outside the contract: every rule skips
//! `#[cfg(test)]` spans (test scaffolding cannot change sim results).
pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn hash_maps_are_fine_in_tests() {
        let mut m = HashMap::new();
        m.insert(1u32, 2u64);
        m.retain(|_, v| {
            let mut keys: Vec<u32> = vec![*v as u32];
            keys.sort_unstable();
            !keys.is_empty()
        });
        assert_eq!(super::double(2), 4);
    }
}
