//! D05 fixture: `Ghost` is declared but never dispatched here nor
//! produced under sim/, and `misses` is missing from the merge.
pub enum RecordKind {
    Hit,
    Ghost,
}

pub struct Counters {
    pub hits: u64,
    pub misses: u64,
}

impl Counters {
    pub fn merge(&mut self, other: &Counters) {
        self.hits += other.hits;
    }
}

pub fn record(kind: RecordKind, c: &mut Counters) {
    if let RecordKind::Hit = kind {
        c.hits += 1;
    }
}
