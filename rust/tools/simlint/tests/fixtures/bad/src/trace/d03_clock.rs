//! D03 fixture: wall clock on a determinism-critical path.
use std::time::Instant;

pub fn stamp() -> u128 {
    Instant::now().elapsed().as_nanos()
}
