//! D00 fixture: a reasonless allow is itself a finding and suppresses
//! nothing — the HashSet it decorates must still trip D01.
use std::collections::HashSet; // simlint: allow(D01)

pub type Funcs = HashSet<u32>;
