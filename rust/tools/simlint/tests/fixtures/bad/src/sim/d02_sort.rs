//! D02 fixture: unstable sort on an arrival stream.
pub fn order(mut events: Vec<(u64, u32)>) -> Vec<(u64, u32)> {
    events.sort_unstable_by_key(|e| e.0);
    events
}
