//! D01 fixture: unspecified-iteration-order containers on a sim path.
use std::collections::HashMap;

pub struct ShareState {
    pub deflated: HashMap<(usize, u32), u64>,
}
