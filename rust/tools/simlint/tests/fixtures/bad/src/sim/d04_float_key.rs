//! D04 fixture: a float comparator on a sim path. The `f64` in the
//! signature must NOT trip the rule — only the comparator argument does.
pub fn rank(mut xs: Vec<(u64, f64)>) -> Vec<(u64, f64)> {
    xs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    xs
}
