//! End-to-end rule-catalog tests over the committed fixture trees
//! (`tests/fixtures/{bad,good}/src`), plus the test that keeps the
//! real crate clean: scanning `rust/src` with the committed baseline
//! must produce zero diagnostics and zero grandfathered D01 entries
//! under `sim/`.

use std::path::{Path, PathBuf};

use simlint::{baseline, check_root, Baseline, CheckOutcome, Diagnostic};

fn fixture(tree: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(tree)
        .join("src")
}

fn run(tree: &str, b: Option<&Baseline>) -> CheckOutcome {
    check_root(&fixture(tree), b).expect("fixture tree scans")
}

fn by_rule<'a>(o: &'a CheckOutcome, rule: &str) -> Vec<&'a Diagnostic> {
    o.diagnostics.iter().filter(|d| d.rule == rule).collect()
}

#[test]
fn bad_tree_trips_every_rule() {
    let o = run("bad", None);
    assert_eq!(o.files_scanned, 6);
    assert_eq!(o.suppressed_allows, 0);

    // D01: two HashMap sites in d01_state.rs plus two HashSet sites in
    // d00_bad_allow.rs (its reasonless allow suppresses nothing).
    let d01 = by_rule(&o, "D01");
    assert_eq!(d01.len(), 4, "{d01:?}");
    assert!(d01.iter().all(|d| d.path.starts_with("sim/")));

    let d02 = by_rule(&o, "D02");
    assert_eq!(d02.len(), 1, "{d02:?}");
    assert_eq!((d02[0].path.as_str(), d02[0].line), ("sim/d02_sort.rs", 3));
    assert!(d02[0].message.contains("sort_unstable_by_key"));

    // D03: the `use` and the call site both trip.
    let d03 = by_rule(&o, "D03");
    assert_eq!(d03.len(), 2, "{d03:?}");
    assert!(d03.iter().all(|d| d.path == "trace/d03_clock.rs"));

    // D04: exactly one finding — the comparator body, not the `f64`s
    // in the function signature.
    let d04 = by_rule(&o, "D04");
    assert_eq!(d04.len(), 1, "{d04:?}");
    assert_eq!(d04[0].path, "sim/d04_float_key.rs");
    assert!(d04[0].message.contains("partial_cmp"));

    // D00: the reasonless `// simlint: allow(D01)` is itself a finding.
    let d00 = by_rule(&o, "D00");
    assert_eq!(d00.len(), 1, "{d00:?}");
    assert_eq!(d00[0].path, "sim/d00_bad_allow.rs");

    // D05: Ghost is neither dispatched nor produced, Hit is dispatched
    // but never produced under sim/, and `misses` is not merged.
    let d05 = by_rule(&o, "D05");
    assert_eq!(d05.len(), 4, "{d05:?}");
    assert!(d05.iter().all(|d| d.path == "metrics/mod.rs"));
    let msgs: Vec<&str> = d05.iter().map(|d| d.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("Ghost") && m.contains("dispatched")));
    assert!(msgs.iter().any(|m| m.contains("Ghost") && m.contains("produced")));
    assert!(msgs.iter().any(|m| m.contains("Hit") && m.contains("produced")));
    assert!(msgs.iter().any(|m| m.contains("misses") && m.contains("merge")));

    assert_eq!(o.diagnostics.len(), 13);
    // check_root sorts by (path, line, rule) for deterministic output.
    let mut sorted: Vec<_> = o
        .diagnostics
        .iter()
        .map(|d| (d.path.clone(), d.line, d.rule))
        .collect();
    let before = sorted.clone();
    sorted.sort();
    assert_eq!(before, sorted);
}

#[test]
fn good_tree_is_clean_and_exercises_the_escape_hatch() {
    let o = run("good", None);
    assert_eq!(o.files_scanned, 6);
    assert!(o.is_clean(), "unexpected findings: {:?}", o.diagnostics);
    // sim/allowed.rs carries exactly one reasoned allow(D02).
    assert_eq!(o.suppressed_allows, 1);
}

#[test]
fn baseline_suppresses_matches_and_reports_stale_entries() {
    let text = "# test baseline\n\
                D01\tsim/d01_state.rs\tuse std::collections::HashMap;\n\
                D02\tsim/gone.rs\tv.sort_unstable();\n";
    let b = Baseline::parse(text).expect("well-formed baseline");
    let o = run("bad", Some(&b));
    assert_eq!(o.suppressed_baseline, 1);
    assert_eq!(by_rule(&o, "D01").len(), 3);
    // The entry for a file that no longer trips is reported stale.
    assert_eq!(o.unused_baseline.len(), 1);
    assert_eq!(o.unused_baseline[0].path, "sim/gone.rs");
}

#[test]
fn written_baseline_grandfathers_the_whole_tree() {
    let raw = run("bad", None);
    let b = Baseline::parse(&baseline::render(&raw.diagnostics)).expect("rendered baseline parses");
    let o = run("bad", Some(&b));
    assert!(o.is_clean(), "baselined tree still trips: {:?}", o.diagnostics);
    assert_eq!(o.suppressed_baseline, 13);
    assert!(o.unused_baseline.is_empty());
}

/// The acceptance gate for the crate itself: `rust/src` under the
/// committed baseline has zero findings, the baseline grandfathers no
/// D01 under `sim/` (slo.rs was fixed, not grandfathered), and the one
/// inline allow (`sim/event.rs` extract_node_completions) is live.
#[test]
fn repo_tree_is_clean_under_committed_baseline() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src = manifest.join("../../src");
    let baseline_path = manifest.join("baseline.txt");
    let b = Baseline::load(&baseline_path).expect("committed baseline parses");
    assert!(
        !b.entries
            .iter()
            .any(|e| e.rule == "D01" && e.path.starts_with("sim/")),
        "no D01 may be grandfathered under sim/: {:?}",
        b.entries
    );
    let o = check_root(&src, Some(&b)).expect("rust/src scans");
    assert!(
        o.is_clean(),
        "determinism contract violated in rust/src:\n{}",
        o.diagnostics
            .iter()
            .map(simlint::Diagnostic::render_text)
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        o.unused_baseline.is_empty(),
        "stale baseline entries: {:?}",
        o.unused_baseline
    );
    assert!(o.suppressed_allows >= 1, "the sim/event.rs allow(D02) should be live");
    assert!(o.files_scanned > 20, "scan rooted wrong? saw {} files", o.files_scanned);
}
