//! §6.5 stress test at full paper scale: a 2-hour bursty trace with
//! 4–5 million invocations against a 10 GB node, KiSS vs baseline.
//!
//! ```sh
//! cargo run --release --example stress_test            # full 4-5M events
//! cargo run --release --example stress_test -- 0.1     # 10% scale
//! ```

use std::time::Instant;

use kiss_faas::experiments::stress;

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    println!("running §6.5 stress test at {:.0}% scale...", scale * 100.0);
    let t0 = Instant::now();
    let (kiss, base) = stress::stress(10, scale, 2025);
    let wall = t0.elapsed();
    println!("{}", stress::render(&kiss, &base));
    println!(
        "simulated {} invocations x2 configs in {:.1} s ({:.2} M events/s)",
        kiss.total_invocations,
        wall.as_secs_f64(),
        (kiss.total_invocations * 2) as f64 / wall.as_secs_f64() / 1e6
    );
    println!(
        "\nhit-rate uplift: {:.2}% -> {:.2}% ({:.1}x, paper: 0.38% -> 2.85%)",
        base.hit_rate_pct,
        kiss.hit_rate_pct,
        kiss.hit_rate_pct / base.hit_rate_pct.max(1e-9)
    );
}
