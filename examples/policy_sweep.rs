//! Policy & split sweep: the paper's §6.4 policy-independence claim on
//! one node size, as a grid — every (split, policy) cell vs the baseline.
//!
//! ```sh
//! cargo run --release --example policy_sweep [-- <mem_gb>]
//! ```

use kiss_faas::coordinator::policy::PolicyKind;
use kiss_faas::coordinator::Balancer;
use kiss_faas::experiments::paper_workload;
use kiss_faas::sim::{run_trace_with, InitOccupancy};
use kiss_faas::trace::synth::synthesize;

fn main() {
    let mem_gb: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    let mut synth = paper_workload();
    synth.duration_us = 1_800_000_000;
    let trace = synthesize(&synth);
    println!(
        "node {mem_gb} GB | {} invocations | cold-start %(drop %)\n",
        trace.events.len()
    );

    print!("{:>8}", "split");
    for kind in PolicyKind::ALL {
        print!("{:>18}", kind.label().to_uppercase());
    }
    println!();

    for split in [0.9, 0.8, 0.7, 0.6, 0.5] {
        print!("{:>5.0}-{:<2.0}", split * 100.0, (1.0 - split) * 100.0);
        for kind in PolicyKind::ALL {
            let mut b = Balancer::kiss(mem_gb * 1024, split, 200, kind, kind);
            let r = run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory);
            print!(
                "{:>11.2}({:>4.1})",
                r.overall.cold_start_pct(),
                r.overall.drop_pct()
            );
        }
        println!();
    }

    print!("{:>8}", "unified");
    for kind in PolicyKind::ALL {
        let mut b = Balancer::baseline(mem_gb * 1024, kind);
        let r = run_trace_with(&trace, &mut b, InitOccupancy::HoldsMemory);
        print!(
            "{:>11.2}({:>4.1})",
            r.overall.cold_start_pct(),
            r.overall.drop_pct()
        );
    }
    println!("\n\nReading: cold-start percentage (drop percentage). The spread across");
    println!("policy columns is small relative to the partitioned-vs-unified gap —");
    println!("the partition, not the replacement policy, carries the benefit (§6.4).");
}
