//! END-TO-END DRIVER: a live KiSS edge node serving *real* model
//! inference through the full three-layer stack.
//!
//!   Layer 1  Pallas fused_linear / row_softmax kernels (python)
//!   Layer 2  iot_mlp + analytics_transformer JAX payloads (python)
//!   —— AOT:  `make artifacts` lowers both to HLO text ——
//!   Layer 3  this binary: KiSS balancer + PJRT runtime + batcher
//!
//! The driver deploys a fleet of small (IoT-MLP) and large (transformer)
//! functions on a memory-constrained node, replays a synthesized edge
//! request schedule against it, batches compatible requests, and reports
//! *measured* latency percentiles and throughput per outcome class,
//! plus the KiSS pool statistics. Compare with `--baseline`.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example edge_iot_serving            # KiSS 80-20
//! cargo run --release --example edge_iot_serving -- --baseline
//! cargo run --release --example edge_iot_serving -- --requests 400
//! ```

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use kiss_faas::config::{Mode, SimConfig};
use kiss_faas::metrics::RecordKind;
use kiss_faas::serve::node::EdgeNode;
use kiss_faas::serve::Batcher;
use kiss_faas::trace::{FunctionId, FunctionProfile, SizeClass};
use kiss_faas::util::rng::Pcg64;
use kiss_faas::util::stats::percentile;

const SMALL_FNS: usize = 24;
const LARGE_FNS: usize = 3;

fn profile(mem_mb: u32, class: SizeClass) -> FunctionProfile {
    FunctionProfile {
        id: FunctionId(0), // assigned by deploy()
        app_id: 0,
        mem_mb,
        app_mem_mb: mem_mb,
        cold_start_us: 0,
        warm_start_us: 0,
        exec_us_mean: 0,
        class,
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let baseline = args.iter().any(|a| a == "--baseline");
    let requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(240);
    let mem_gb: u64 = args
        .iter()
        .position(|a| a == "--mem-gb")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);

    let mut cfg = SimConfig::edge_default(mem_gb * 1024);
    if baseline {
        cfg.mode = Mode::Baseline;
    }
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut node = EdgeNode::new(&cfg, &artifacts)?;

    // Deploy the fleet: 24 small IoT classifiers (30-60 MB) and 3 large
    // analytics transformers (300-400 MB), the paper's two classes.
    let mut rng = Pcg64::new(7);
    let mut small_ids = Vec::new();
    let mut large_ids = Vec::new();
    for _ in 0..SMALL_FNS {
        let mem = rng.range_u64(30, 60) as u32;
        small_ids.push(node.deploy(profile(mem, SizeClass::Small), "iot_mlp_b1")?);
    }
    for _ in 0..LARGE_FNS {
        let mem = rng.range_u64(300, 400) as u32;
        large_ids.push(node.deploy(
            profile(mem, SizeClass::Large),
            "analytics_transformer_b1",
        )?);
    }
    println!(
        "node: {} | {} partitions | {} small + {} large functions | {requests} requests",
        cfg.describe(),
        node.occupancy().len(),
        SMALL_FNS,
        LARGE_FNS
    );

    // Request schedule: Zipf-skewed over small functions (5x the large
    // volume), round-robin over large.
    let zipf = kiss_faas::util::rng::ZipfTable::new(SMALL_FNS, 1.1);
    #[derive(Clone, Copy, PartialEq, Eq, Hash)]
    enum Kind {
        Small,
        Large,
    }
    let mut schedule: Vec<(Kind, FunctionId)> = Vec::with_capacity(requests);
    for i in 0..requests {
        if i % 6 == 5 {
            schedule.push((Kind::Large, large_ids[i % LARGE_FNS]));
        } else {
            let rank = zipf.sample(&mut rng) as usize - 1;
            schedule.push((Kind::Small, small_ids[rank]));
        }
    }

    // Serve: batch small requests per function through the b1/b8
    // variants; large requests go straight through.
    let mlp_input = |rng: &mut Pcg64| -> Vec<f32> {
        (0..64).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    };
    let tfm_input = |rng: &mut Pcg64| -> Vec<f32> {
        (0..128 * 256).map(|_| rng.normal(0.0, 0.5) as f32).collect()
    };

    let mut lat_by_kind: HashMap<(Kind, RecordKind), Vec<f64>> = HashMap::new();
    let mut batchers: HashMap<u32, Batcher> = HashMap::new();
    let t0 = Instant::now();
    let mut served_samples = 0usize;

    for (kind, fid) in &schedule {
        match kind {
            Kind::Large => {
                let x = tfm_input(&mut rng);
                let res = node.invoke(*fid, &x)?;
                lat_by_kind
                    .entry((Kind::Large, res.outcome_kind))
                    .or_default()
                    .push(res.latency.as_secs_f64() * 1e3);
                served_samples += 1;
            }
            Kind::Small => {
                let batcher = batchers
                    .entry(fid.0)
                    .or_insert_with(|| Batcher::new(node.batch_sizes(*fid)));
                batcher.push(mlp_input(&mut rng));
                if batcher.should_drain() {
                    for (bsz, packed) in batcher.drain() {
                        let res = node.invoke_batch(*fid, &packed, bsz)?;
                        let per = res.latency.as_secs_f64() * 1e3 / bsz as f64;
                        for _ in 0..bsz {
                            lat_by_kind
                                .entry((Kind::Small, res.outcome_kind))
                                .or_default()
                                .push(per);
                        }
                        served_samples += bsz;
                    }
                }
            }
        }
    }
    // Flush remaining batched requests.
    for (fid, batcher) in batchers.iter_mut() {
        for (bsz, packed) in batcher.drain() {
            let res = node.invoke_batch(FunctionId(*fid), &packed, bsz)?;
            let per = res.latency.as_secs_f64() * 1e3 / bsz as f64;
            for _ in 0..bsz {
                lat_by_kind
                    .entry((Kind::Small, res.outcome_kind))
                    .or_default()
                    .push(per);
            }
            served_samples += bsz;
        }
    }
    let wall = t0.elapsed();

    // ----- report ----------------------------------------------------- //
    println!(
        "\nserved {served_samples} requests in {:.2} s -> {:.1} req/s (measured, real inference)",
        wall.as_secs_f64(),
        served_samples as f64 / wall.as_secs_f64()
    );
    println!(
        "\n{:<26} {:>8} {:>12} {:>12} {:>12}",
        "class/outcome", "count", "p50 (ms)", "p95 (ms)", "max (ms)"
    );
    let mut keys: Vec<_> = lat_by_kind.keys().copied().collect();
    keys.sort_by_key(|(k, o)| {
        (matches!(k, Kind::Large) as u8, format!("{o:?}"))
    });
    for key in keys {
        let lats = &lat_by_kind[&key];
        let (kind, outcome) = key;
        let label = format!(
            "{}/{}",
            if kind == Kind::Small { "small(iot_mlp)" } else { "large(transformer)" },
            match outcome {
                RecordKind::Hit => "warm",
                RecordKind::Miss => "cold",
                RecordKind::Drop => "drop",
                RecordKind::Offload => "offload",
                RecordKind::Migrate { .. } => "migrate",
            }
        );
        if lats.is_empty() {
            continue;
        }
        println!(
            "{:<26} {:>8} {:>12.2} {:>12.2} {:>12.2}",
            label,
            lats.len(),
            percentile(lats, 50.0),
            percentile(lats, 95.0),
            lats.iter().cloned().fold(0.0, f64::max),
        );
    }

    let r = &node.report;
    println!(
        "\ncoordinator: hits {} | cold {} | drops {} | cold-start {:.1}% | hit-rate {:.1}%",
        r.overall.hits,
        r.overall.misses,
        r.overall.drops,
        r.overall.cold_start_pct(),
        r.overall.hit_rate_pct()
    );
    for (i, (used, cap)) in node.occupancy().iter().enumerate() {
        println!("  pool {i}: {used}/{cap} MB resident");
    }
    println!("\n(run with --baseline to compare the unified pool on the same schedule)");
    Ok(())
}
