//! Quickstart: simulate a KiSS edge node vs the unified baseline on a
//! synthesized edge workload and print the paper's core metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use kiss_faas::config::SimConfig;
use kiss_faas::experiments::{paper_workload, run_on};
use kiss_faas::trace::synth::synthesize;

fn main() {
    // A 6 GB edge node — squarely in the paper's constrained band.
    let mut synth = paper_workload();
    synth.duration_us = 1_800_000_000; // 30 min keeps this interactive
    let trace = synthesize(&synth);
    println!(
        "workload: {} invocations over {} s ({} small fns, {} large fns)\n",
        trace.events.len(),
        trace.duration_us() / 1_000_000,
        synth.n_small,
        synth.n_large
    );

    let mut kiss = SimConfig::edge_default(6 * 1024);
    kiss.synth = synth.clone();
    let mut base = SimConfig::baseline_default(6 * 1024);
    base.synth = synth.clone();

    let rk = run_on(&trace, &kiss);
    let rb = run_on(&trace, &base);

    println!("{:<22} {:>12} {:>12}", "metric", "kiss-80-20", "baseline");
    println!(
        "{:<22} {:>11.2}% {:>11.2}%",
        "cold-start overall",
        rk.overall.cold_start_pct(),
        rb.overall.cold_start_pct()
    );
    println!(
        "{:<22} {:>11.2}% {:>11.2}%",
        "cold-start small",
        rk.small.cold_start_pct(),
        rb.small.cold_start_pct()
    );
    println!(
        "{:<22} {:>11.2}% {:>11.2}%",
        "cold-start large",
        rk.large.cold_start_pct(),
        rb.large.cold_start_pct()
    );
    println!(
        "{:<22} {:>11.2}% {:>11.2}%",
        "drops overall",
        rk.overall.drop_pct(),
        rb.overall.drop_pct()
    );
    println!(
        "{:<22} {:>11.2}% {:>11.2}%",
        "warm hit rate",
        rk.overall.hit_rate_pct(),
        rb.overall.hit_rate_pct()
    );

    let reduction = (rb.overall.cold_start_pct() - rk.overall.cold_start_pct())
        / rb.overall.cold_start_pct().max(1e-9)
        * 100.0;
    println!("\nKiSS reduces overall cold starts by {reduction:.1}% on this node.");
}
